"""Quickstart: park payloads on the 'switch', run a shallow NF chain on
headers only, merge, and verify wire-level functional equivalence.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.park import ParkConfig, init_state, split, merge, stats
from repro.core.packet import wire_bytes
from repro.nf.chain import Chain
from repro.nf.firewall import Firewall
from repro.nf.nat import Nat
from repro.switchsim.simulate import baseline_roundtrip
from repro.traffic.generator import enterprise


def main():
    wl = enterprise()
    pkts = wl.make_batch(jax.random.key(0), 256, pmax=2048)
    print(f"workload: {wl.name}, mean packet {wl.mean_pkt_bytes:.0f}B")

    cfg = ParkConfig(capacity=512, max_exp=2)
    state = init_state(cfg)

    # Split: park payloads, forward headers (+ un-parked tails)
    state, to_server = split(cfg, state, pkts)
    in_bytes = int(jnp.sum(pkts.pkt_len()))
    srv_bytes = int(jnp.sum(to_server.pkt_len()))
    print(f"switch->server bytes: {srv_bytes} vs {in_bytes} "
          f"({100 * (1 - srv_bytes / in_bytes):.1f}% parked)")

    # Shallow NFs see only headers
    chain = Chain((Firewall(rules=(int(pkts.src_ip[3]),)), Nat()))
    cstate = chain.init_state()
    cstate, from_server, dropped, cycles = chain.run(cstate, to_server)
    print(f"chain dropped {int(dropped.sum())} packets, "
          f"{cycles:.0f} cycles/pkt")

    # Merge: re-attach parked payloads
    state, out = merge(cfg, state, from_server)
    print("switch counters:", stats(state))

    # Functional equivalence vs running the chain on whole packets
    ref, _, _ = baseline_roundtrip(chain, pkts)
    got, _ = wire_bytes(out)
    want, _ = wire_bytes(ref)
    assert bool(jnp.all(got == want)), "wire mismatch!"
    print("wire-level functional equivalence: OK (paper §6.2.6)")


if __name__ == "__main__":
    main()
