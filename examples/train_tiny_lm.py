"""End-to-end training driver demo: trains a reduced-config model for a few
hundred steps with checkpointing, kills it halfway, and resumes — the
fault-tolerance path a real fleet uses.

    PYTHONPATH=src python examples/train_tiny_lm.py [--arch qwen2.5-3b]
"""
import argparse
import shutil
import tempfile

from repro.launch.train import RunConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        half = args.steps // 2
        print(f"=== phase 1: train to step {half}, then 'crash' ===")
        out1 = train(RunConfig(arch=args.arch, steps=half, seq_len=128,
                               global_batch=8, lr=3e-3, ckpt_dir=ckpt,
                               ckpt_every=half // 2, log_every=20))
        print(f"=== phase 2: restart; auto-resumes from the checkpoint ===")
        out2 = train(RunConfig(arch=args.arch, steps=args.steps, seq_len=128,
                               global_batch=8, lr=3e-3, ckpt_dir=ckpt,
                               ckpt_every=half // 2, log_every=20))
        print(f"loss: start={out1['losses'][0]:.3f} "
              f"mid={out1['losses'][-1]:.3f} final={out2['losses'][-1]:.3f}")
        assert out2["losses"][-1] < out1["losses"][0], "no learning?"
        print("training + restart: OK")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
