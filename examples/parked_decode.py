"""Serving with parked KV pages: the paper's Split/Merge/Evict machinery
running as a paged-KV allocator, with header-only routing accounting.

    PYTHONPATH=src python examples/parked_decode.py
"""
import jax

from repro import configs
from repro.configs.reduced import reduced
from repro.models.lm import LM
from repro.serving.engine import EngineConfig, ServeEngine
from repro.serving.pool import PoolConfig


def main():
    cfg = reduced(configs.get("gemma-7b"))
    lm = LM(cfg, remat_policy="off")
    params = lm.init_params(jax.random.key(0))
    eng = ServeEngine(lm, params, EngineConfig(
        max_batch=4, max_pages_per_req=16,
        pool=PoolConfig(num_pages=128, page_tokens=8, max_exp=2)))

    print("admitting 3 requests (prefill -> parked pages)...")
    eng.admit(1, [5, 3, 8, 1])
    eng.admit(2, [9, 9, 2])
    eng.admit(3, [4, 4, 4, 4, 4, 4])
    for step in range(6):
        eng.step()

    print("request 2 cancelled mid-flight (Explicit Drop frees its pages)")
    eng.finish(2, cancel=True)
    out1 = eng.finish(1)
    out3 = eng.finish(3)
    print(f"request 1 -> {out1}")
    print(f"request 3 -> {out3}")

    s = eng.stats()
    print("\npool counters (the paper's Split/Merge/Evict set):")
    for k in ("splits", "merges", "explicit_drops", "evictions",
              "premature_evictions", "occupancy"):
        print(f"  {k:22s} {s[k]}")
    print(f"\nheader bytes routed:      {s['header_bytes']}")
    print(f"payload bytes kept parked: {s['payload_bytes_avoided']}")
    print(f"serving goodput gain:      {s['goodput_gain']:.0f}x "
          f"(the paper's Fig. 8 effect, at KV-page scale)")


if __name__ == "__main__":
    main()
