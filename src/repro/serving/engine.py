"""Batched serving engine with parked KV pages and header-only routing.

The production story (DESIGN.md §2b): KV pages are *parked* in per-shard
pools; the scheduler/router moves only ``RequestHeader``s — request id, last
token, position, page tags (id, generation) — between pods.  This module
implements the single-shard engine: admission (prefill -> pages), batched
decode steps against the paged pool, completion/cancel (release = Merge /
Explicit Drop), and the eviction pathology (abandoned requests' pages age out
via the expiry threshold; a prematurely evicted page fails its generation
check and the request is dropped + counted — the paper's §6.2.4 semantics).

For simplicity the reference engine supports the dense-GQA families (paged
KV); recurrent-state archs park their fixed-size state as a single page.
The jnp gather path is the default; the kernelized variant routes attention
through the Pallas paged kernel (repro.kernels.paged_attention).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models.lm import LM, segments_for
from repro.serving import pool as pool_mod
from repro.serving.pool import PoolConfig

HEADER_BYTES_PER_PAGE = 8   # (page_id u32-ish, generation u16, crc u16)
HEADER_FIXED_BYTES = 16     # request id, last token, position, flags


@dataclasses.dataclass
class RequestHeader:
    """What actually crosses the pod/data axes per request per step."""
    rid: int
    token: int
    position: int
    pages: np.ndarray   # (MP,) int32, -1 padded
    gens: np.ndarray    # (MP,) int32

    def wire_bytes(self) -> int:
        live = int((self.pages >= 0).sum())
        return HEADER_FIXED_BYTES + HEADER_BYTES_PER_PAGE * live


def parked_payload_bytes(cfg: ModelConfig, position: int) -> int:
    """Bytes that would cross the wire per request per hop WITHOUT parking
    (the whole KV state) — the serving analogue of the paper's payload."""
    if cfg.family == "ssm":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        nheads = d_in // s.head_dim
        return cfg.num_layers * nheads * s.d_state * s.head_dim * 4
    if cfg.mla is not None:
        per_tok = cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim
        return cfg.num_layers * position * per_tok * 2
    per_tok = 2 * cfg.num_kv_heads * cfg.head_dim
    return cfg.num_layers * position * per_tok * 2


# frozen (RPL004): *Config classes are hashable-static-arg currency; the
# engine mutates its own arrays, never this config
@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 8
    max_pages_per_req: int = 16
    pool: PoolConfig = dataclasses.field(
        default_factory=lambda: PoolConfig(num_pages=128, page_tokens=16))


class ServeEngine:
    """Single-shard reference engine (dense/GQA archs)."""

    def __init__(self, lm: LM, params, ecfg: EngineConfig):
        cfg = lm.cfg
        assert cfg.family in ("dense", "moe", "vlm") and cfg.mla is None, \
            "reference engine supports paged GQA archs"
        self.lm = lm
        self.params = params
        self.ecfg = ecfg
        self.pool = pool_mod.init_pool(ecfg.pool)
        p = ecfg.pool
        segs = segments_for(cfg)
        (self.seg,) = segs
        kv_shape = (self.seg.count, p.num_pages, p.page_tokens,
                    cfg.num_kv_heads, cfg.head_dim)
        self.k_pages = jnp.zeros(kv_shape, cm.DTYPE)
        self.v_pages = jnp.zeros(kv_shape, cm.DTYPE)
        # request slots
        mb, mp = ecfg.max_batch, ecfg.max_pages_per_req
        self.active = np.zeros((mb,), bool)
        self.rid = np.full((mb,), -1, np.int64)
        self.pos = np.zeros((mb,), np.int32)
        self.last_tok = np.zeros((mb,), np.int32)
        self.pages = np.full((mb, mp), -1, np.int32)
        self.gens = np.zeros((mb, mp), np.int32)
        self.dropped: list[int] = []
        self.finished: dict[int, list[int]] = {}
        self.header_bytes_total = 0
        self.payload_bytes_avoided = 0

    # -- page bookkeeping ----------------------------------------------------
    def _ensure_page(self, slot: int) -> bool:
        """Allocate the page for self.pos[slot] if not yet present."""
        p = self.ecfg.pool
        need_idx = self.pos[slot] // p.page_tokens
        if need_idx >= self.ecfg.max_pages_per_req:
            return False
        if self.pages[slot, need_idx] >= 0:
            return True
        want = jnp.zeros((1,), bool).at[0].set(True)
        self.pool, pg, gen, ok = pool_mod.alloc(p, self.pool, want)
        if not bool(ok[0]):
            return False
        self.pages[slot, need_idx] = int(pg[0])
        self.gens[slot, need_idx] = int(gen[0])
        return True

    def _write_kv(self, slot: int, k_new, v_new) -> None:
        """k_new/v_new: (L, K, E) for the current position."""
        p = self.ecfg.pool
        page = int(self.pages[slot, self.pos[slot] // p.page_tokens])
        off = int(self.pos[slot] % p.page_tokens)
        self.k_pages = self.k_pages.at[:, page, off].set(k_new)
        self.v_pages = self.v_pages.at[:, page, off].set(v_new)

    # -- admission -------------------------------------------------------------
    def admit(self, rid: int, prompt: list[int]) -> bool:
        free = np.where(~self.active)[0]
        if len(free) == 0:
            return False
        slot = int(free[0])
        self.active[slot] = True
        self.rid[slot] = rid
        self.pos[slot] = 0
        self.pages[slot] = -1
        self.gens[slot] = 0
        self.finished[rid] = list(prompt)
        # sequential prefill through the decode path (tiny reference engine;
        # the dry-run prefill path is the batched version).  Only the final
        # prompt token's logits produce a generated token.
        for i, tok in enumerate(prompt):
            if not self._step_one(slot, tok, record=(i == len(prompt) - 1)):
                return False
        return True

    # -- decode -------------------------------------------------------------------
    def _step_one(self, slot: int, token: int, record: bool = True) -> bool:
        """Advance one request by one token.  Returns False on drop."""
        cfg = self.lm.cfg
        if not self._ensure_page(slot):
            self._drop(slot)
            return False
        # validate every page generation (Merge stage-2 check)
        okv = pool_mod.validate(self.pool, jnp.asarray(self.pages[slot]),
                                jnp.asarray(self.gens[slot]))
        if not bool(okv):
            self._drop(slot, premature=True)
            return False
        logits, k_new, v_new = self._forward_token(slot, token)
        self._write_kv(slot, k_new, v_new)
        self.last_tok[slot] = int(jnp.argmax(logits))
        if record:
            self.finished[int(self.rid[slot])].append(
                int(self.last_tok[slot]))
        self.pos[slot] += 1
        # header-only routing accounting
        hdr = RequestHeader(int(self.rid[slot]), token, int(self.pos[slot]),
                            self.pages[slot], self.gens[slot])
        self.header_bytes_total += hdr.wire_bytes()
        self.payload_bytes_avoided += parked_payload_bytes(
            cfg, int(self.pos[slot]))
        return True

    def _forward_token(self, slot: int, token: int):
        """Run the decoder stack for one token of one request using the
        paged pool for attention.  Returns (logits, k_new (L,K,E), v_new)."""
        cfg = self.lm.cfg
        lmp = self.params
        pos = int(self.pos[slot])
        x = cm.embed_apply(lmp["embed"], jnp.asarray([[token]]), cfg)
        cos, sin = cm.rope_angles(jnp.asarray([[pos]]), cfg.head_dim,
                                  cfg.rope_theta)
        pt = jnp.asarray(self.pages[slot])[None]       # (1, MP)
        lengths = jnp.asarray([pos], jnp.int32)        # attend over history
        k_out, v_out = [], []
        seg_params = lmp[self.seg.name]
        for li in range(self.seg.count):
            pl_ = jax.tree.map(lambda a: a[li], seg_params)["sub0"]
            h = cm.rmsnorm(x, pl_["ln1"], cfg.norm_eps)
            q, k, v = cm.attn_qkv(pl_["attn"], h, cfg, cos, sin)
            k_out.append(k[0, 0])
            v_out.append(v[0, 0])
            o = self._paged_attention(li, q, k, v, pt, lengths)
            x = x + cm.attn_out(pl_["attn"], o)
            h2 = cm.rmsnorm(x, pl_["ln2"], cfg.norm_eps)
            if "router" in pl_["ffn"]:
                from repro.models.moe import moe_apply
                out, _ = moe_apply(pl_["ffn"], h2, cfg, cfg.act)
                x = x + out
            else:
                x = x + cm.mlp_apply(pl_["ffn"], h2, cfg.act)
        x = cm.rmsnorm(x, lmp["final_norm"], cfg.norm_eps)
        logits = cm.unembed_apply(lmp["embed"], x, cfg)[0, 0]
        return logits, jnp.stack(k_out), jnp.stack(v_out)

    def _paged_attention(self, li: int, q, k_new, v_new, pt, lengths):
        """Attention over parked pages + the current token's fresh kv."""
        cfg = self.lm.cfg
        b, s, kh, g, e = 1, 1, cfg.num_kv_heads, \
            cfg.num_heads // cfg.num_kv_heads, cfg.head_dim
        qh = q.reshape(1, kh, g, e)
        hist_len = lengths[0]
        if int(hist_len) == 0:
            o = v_new[:, 0][:, :, None, :]
        else:
            # reference combine: rerun dense softmax over gathered history
            from repro.kernels.paged_attention.ref import NEG_INF
            p_ = self.ecfg.pool
            ptc = jnp.maximum(pt, 0)
            kh_all = self.k_pages[li][ptc].reshape(1, -1, kh, e)
            vh_all = self.v_pages[li][ptc].reshape(1, -1, kh, e)
            k_full = jnp.concatenate([kh_all, k_new], axis=1)
            v_full = jnp.concatenate([vh_all, v_new], axis=1)
            t = k_full.shape[1]
            sc = jnp.einsum("bkge,btke->bkgt", qh, k_full,
                            preferred_element_type=jnp.float32) * (e ** -0.5)
            posn = jnp.arange(t)[None]
            valid = (posn < hist_len) | (posn == t - 1)
            page_live = (pt >= 0).repeat(p_.page_tokens, axis=1)
            page_live = jnp.concatenate(
                [page_live, jnp.ones((1, 1), bool)], axis=1)
            sc = jnp.where((valid & page_live)[:, None, None], sc, NEG_INF)
            w = jax.nn.softmax(sc, axis=-1)
            o = jnp.einsum("bkgt,btke->bkge", w.astype(v_full.dtype), v_full)
        return o.reshape(1, 1, kh, g, e)

    def step(self) -> None:
        """One decode step for every active request."""
        for slot in np.where(self.active)[0]:
            self._step_one(int(slot), int(self.last_tok[slot]))

    # -- completion ------------------------------------------------------------
    def finish(self, rid: int, cancel: bool = False) -> Optional[list[int]]:
        """Merge (normal completion) or Explicit Drop (cancel)."""
        slots = np.where(self.active & (self.rid == rid))[0]
        if len(slots) == 0:
            return None
        slot = int(slots[0])
        self.pool = pool_mod.release(
            self.ecfg.pool, self.pool, jnp.asarray(self.pages[slot]),
            jnp.asarray(self.gens[slot]), explicit=cancel)
        self.active[slot] = False
        return self.finished.pop(int(self.rid[slot]), None)

    def _drop(self, slot: int, premature: bool = False) -> None:
        self.dropped.append(int(self.rid[slot]))
        self.pool = pool_mod.release(
            self.ecfg.pool, self.pool, jnp.asarray(self.pages[slot]),
            jnp.asarray(self.gens[slot]), explicit=True)
        self.active[slot] = False

    # -- stats --------------------------------------------------------------------
    def stats(self) -> dict[str, Any]:
        from repro.core import counters as C
        d = C.as_dict(self.pool.counters)
        d["occupancy"] = int(pool_mod.occupancy(self.pool))
        d["header_bytes"] = self.header_bytes_total
        d["payload_bytes_avoided"] = self.payload_bytes_avoided
        d["goodput_gain"] = (
            self.payload_bytes_avoided
            / max(self.header_bytes_total, 1))
        return d
