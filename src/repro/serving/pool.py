"""Paged-KV allocator = the PayloadPark lookup table at page granularity.

The paper's metadata-table machinery (DESIGN.md §2b), re-instantiated for LM
serving: a KV-cache *page* is the parked payload; the compact request header
(page ids + generations + position + last token) is what travels between the
router and the model shards.  Mapping:

  paper                         serving pool
  -----                         ------------
  Split stores 160B payload     admit/extend allocates a page
  circular TI + single probe    same (alloc scan, one probe per page)
  EXP expiry decrement          same (abandoned requests' pages reclaimed)
  generation (CLK) check        validate() before every attention gather
  Merge frees the slot          release() on request completion
  Explicit Drop (OP bit)        release() on client cancel — immediate
  premature-eviction counter    same (request must restart)
  ENB=0 fallback                alloc failure -> request queued, not parked

The allocator state is tiny (3 int32 vectors) and lives on every shard that
owns pages; all bulk KV stays put — only headers cross the mesh.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import counters as C


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    num_pages: int
    page_tokens: int = 128
    max_exp: int = 2
    max_clk: int = 1 << 16


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PoolState:
    tbl_idx: jax.Array   # () int32
    clk: jax.Array       # () int32
    meta_exp: jax.Array  # (M,) int32
    meta_clk: jax.Array  # (M,) int32 — generation, 0 = free
    counters: jax.Array  # (C.NUM,) int32 (the paper's counter set)


def init_pool(cfg: PoolConfig) -> PoolState:
    m = cfg.num_pages
    return PoolState(
        tbl_idx=jnp.zeros((), jnp.int32),
        clk=jnp.zeros((), jnp.int32),
        meta_exp=jnp.zeros((m,), jnp.int32),
        meta_clk=jnp.zeros((m,), jnp.int32),
        counters=C.zeros(),
    )


def alloc(cfg: PoolConfig, state: PoolState, want: jax.Array):
    """Allocate pages for a batch (Split).  ``want``: (B,) bool — which
    requests need a new page this step.  Single-probe circular allocation
    with expiry-decrement eviction, exactly Alg. 1 stages 1-2.

    Returns (state, page_ids (B,), gens (B,), ok (B,))."""
    m = cfg.num_pages

    def step(carry, w):
        ti, clk, exp_tbl, clk_tbl = carry
        ti_n = jnp.where(w, (ti + 1) % m, ti)
        clk_n = jnp.where(w, clk + 1, clk)
        clk_n = jnp.where(clk_n >= cfg.max_clk, 1, clk_n)
        exp_pre = exp_tbl[ti_n]
        exp_dec = jnp.where(exp_pre >= 1, exp_pre - 1, exp_pre)
        evicted = w & (exp_pre >= 1) & (exp_dec == 0)
        claim = w & (exp_dec == 0)
        new_exp = jnp.where(claim, cfg.max_exp, exp_dec)
        exp_tbl = jnp.where(w, exp_tbl.at[ti_n].set(new_exp), exp_tbl)
        clk_tbl = jnp.where(
            claim, clk_tbl.at[ti_n].set(clk_n),
            jnp.where(evicted, clk_tbl.at[ti_n].set(0), clk_tbl))
        out = (jnp.where(claim, ti_n, -1), jnp.where(claim, clk_n, 0),
               claim, evicted, w & ~claim)
        return (ti_n, clk_n, exp_tbl, clk_tbl), out

    carry0 = (state.tbl_idx, state.clk, state.meta_exp, state.meta_clk)
    (ti, clk, exp_tbl, clk_tbl), (pages, gens, ok, evicted, failed) = \
        jax.lax.scan(step, carry0, want)

    counters = state.counters
    counters = C.bump(counters, "splits", jnp.sum(ok))
    counters = C.bump(counters, "evictions", jnp.sum(evicted))
    counters = C.bump(counters, "skip_occupied", jnp.sum(failed))
    return (PoolState(ti, clk, exp_tbl, clk_tbl, counters),
            pages, gens, ok)


def validate(state: PoolState, pages, gens):
    """Generation check (Merge stage 2) for every page a request claims to
    own.  pages/gens: (..., P) with -1 padding.  Returns (...,) bool all-ok."""
    live = pages >= 0
    got = state.meta_clk[jnp.maximum(pages, 0)]
    ok = jnp.where(live, got == gens, True)
    return jnp.all(ok, axis=-1)


def release(cfg: PoolConfig, state: PoolState, pages, gens, explicit=False):
    """Free pages (Merge / Explicit Drop).  pages/gens: flat (N,) with -1
    padding.  Stale (already-evicted) pages are counted, not freed twice."""
    live = pages >= 0
    idx = jnp.maximum(pages, 0)
    match = live & (state.meta_clk[idx] == gens)
    rows = jnp.where(match, idx, cfg.num_pages)
    meta_exp = state.meta_exp.at[rows].set(0, mode="drop")
    meta_clk = state.meta_clk.at[rows].set(0, mode="drop")
    counters = state.counters
    name = "explicit_drops" if explicit else "merges"
    counters = C.bump(counters, name, jnp.sum(match))
    counters = C.bump(counters, "premature_evictions",
                      jnp.sum(live & ~match))
    return PoolState(state.tbl_idx, state.clk, meta_exp, meta_clk, counters)


def occupancy(state: PoolState):
    return jnp.sum(state.meta_exp > 0)
