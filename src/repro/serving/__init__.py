"""Serving layer: paged KV pool with PayloadPark tag semantics + engine."""
