"""Oracle for the CRC-16 tag kernel: the core's own implementation."""
from repro.core.header import crc16_tag as crc16_tag_ref  # noqa: F401
