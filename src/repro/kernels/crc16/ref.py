"""Oracle for the CRC-16 tag kernel: the backend registry's single jnp
reference implementation (repro.backend.ref)."""
from repro.backend.ref import crc16_tag as crc16_tag_ref  # noqa: F401
