"""jit'd wrapper: flat (B,) tag vectors -> lane-tiled kernel -> (B,) CRCs."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.crc16.kernel import LANES, crc16_kernel


@partial(jax.jit, static_argnames=("interpret",))
def crc16_tag_kernel_op(ti, clk, interpret: bool = True):
    b = ti.shape[0]
    tile = LANES * 8
    pad = (-b) % tile
    tip = jnp.pad(ti.astype(jnp.int32), (0, pad)).reshape(-1, LANES)
    clkp = jnp.pad(clk.astype(jnp.int32), (0, pad)).reshape(-1, LANES)
    out = crc16_kernel(tip, clkp, interpret=interpret)
    return out.reshape(-1)[:b]
