from repro.kernels.crc16.ops import crc16_tag_kernel_op  # noqa: F401
