"""Pallas TPU kernel: CRC-16/CCITT-FALSE over PayloadPark tags.

The tag CRC (paper §3.2) is computed on Split (header construction) and
checked on Merge (header validation) — per-packet, on the hot path.  The
kernel is a fully-unrolled 4-byte x 8-bit branch-free bit loop over an int32
lane vector: TPU VPU-friendly (no data-dependent control flow; predication by
``jnp.where``, the vector analogue of P4 match predication).

Block layout: (BT, 128) tiles — the batch is reshaped to lane-major so each
grid step CRCs BT*128 tags at once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.backend.ref import CRC_INIT, CRC_POLY

LANES = 128


def _crc_kernel(ti_ref, clk_ref, out_ref):
    ti = ti_ref[...]
    clk = clk_ref[...]
    crc = jnp.full_like(ti, CRC_INIT)
    # bytes: ti&0xFF, ti>>8, clk&0xFF, clk>>8 (little-endian tag layout)
    for byte in (ti & 0xFF, (ti >> 8) & 0xFF, clk & 0xFF, (clk >> 8) & 0xFF):
        crc = crc ^ (byte << 8)
        for _ in range(8):
            hi = (crc >> 15) & 1
            crc = (crc << 1) & 0xFFFF
            crc = jnp.where(hi == 1, crc ^ CRC_POLY, crc)
    out_ref[...] = crc


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def crc16_kernel(ti, clk, *, bt: int = 8, interpret: bool = True):
    """ti, clk: (N, LANES) int32 -> (N, LANES) int32 CRCs."""
    n, lanes = ti.shape
    assert lanes == LANES and n % bt == 0, (ti.shape, bt)
    return pl.pallas_call(
        _crc_kernel,
        grid=(n // bt,),
        in_specs=[
            pl.BlockSpec((bt, LANES), lambda t: (t, 0)),
            pl.BlockSpec((bt, LANES), lambda t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((bt, LANES), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((n, LANES), jnp.int32),
        interpret=interpret,
    )(ti, clk)
