"""jit'd public wrapper for payload_store: byte-view plumbing + lane padding.

Converts the core's (M, park_bytes) uint8 table and (B, park_bytes) payload
rows to int32 word lanes, pads the lane count to a multiple of 128 (MXU/VPU
alignment), runs the Pallas kernel, and converts back.  In production the
table would be kept permanently in the padded int32 layout; the per-call
conversion here keeps the faithful byte-level core decoupled from the kernel
layout (and costs nothing under interpret-mode validation on CPU).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.payload_store.kernel import payload_store_kernel

LANES = 128


def _to_words(x):  # (..., 4k) uint8 -> (..., k) int32
    return jax.lax.bitcast_convert_type(
        x.reshape(*x.shape[:-1], x.shape[-1] // 4, 4), jnp.int32)


def _to_bytes(x, nbytes):  # (..., k) int32 -> (..., 4k) uint8
    b = jax.lax.bitcast_convert_type(x, jnp.uint8)
    return b.reshape(*x.shape[:-1], x.shape[-1] * 4)[..., :nbytes]


def _pad_lanes(x):
    w = x.shape[-1]
    pad = (-w) % LANES
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x


@partial(jax.jit, static_argnames=("interpret",))
def payload_store(table_u8, payload_u8, idx, enb, interpret: bool = True):
    """Scatter parked payload rows: table[idx[b]] = payload[b] where enb[b]."""
    m, nbytes = table_u8.shape
    assert nbytes % 4 == 0, nbytes
    b = payload_u8.shape[0]
    tw = _pad_lanes(_to_words(table_u8))
    pw = _pad_lanes(_to_words(payload_u8))
    bt = 8 if b % 8 == 0 else 1
    out = payload_store_kernel(tw, pw, idx.astype(jnp.int32),
                               enb, bt=bt, interpret=interpret)
    return _to_bytes(out[:, : nbytes // 4], nbytes)
