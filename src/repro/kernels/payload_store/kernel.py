"""Pallas TPU kernel: striped payload scatter (paper Alg. 1 stage 3..N).

TPU adaptation of the paper's MAT-column striping (Fig. 4): a parked payload
row is a lane vector of ``W`` int32 words (the paper's P0..PL 16-byte blocks
become contiguous lane groups); the payload table lives in HBM/VMEM as a
(M, W) register file.  One grid step processes a tile of ``BT`` packets and
performs at most one predicated dynamic-slice store per packet — the same
"single stateful access per stage per packet" discipline the Tofino imposes
(§2), which is also what keeps the kernel a pure streaming scatter with no
read-modify-write hazards (tags are unique by construction, §5).

BlockSpecs: the table is one resident VMEM block (index_map pins it for every
grid step; ``input_output_aliases`` makes the update in-place); payload tiles
are (BT, W) VMEM blocks; indices/enables ride in scalar-prefetch (SMEM), the
TPU analogue of PHV metadata fields.  ``W`` is padded to a multiple of 128
lanes by ops.py so every store is lane-aligned.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BT = 8


def _store_kernel(idx_ref, enb_ref, payload_ref, table_in_ref, table_ref, *,
                  bt: int):
    t = pl.program_id(0)

    # Materialize the resident table block once; subsequent grid steps revisit
    # the same block, so VMEM contents persist (standard accumulation pattern).
    @pl.when(t == 0)
    def _():
        table_ref[...] = table_in_ref[...]

    for i in range(bt):  # unrolled: BT predicated stores per grid step
        b = t * bt + i
        row = idx_ref[b]

        @pl.when(enb_ref[b] != 0)
        def _():
            table_ref[pl.ds(row, 1), :] = payload_ref[pl.ds(i, 1), :]


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def payload_store_kernel(table, payload, idx, enb, *, bt: int = DEFAULT_BT,
                         interpret: bool = True):
    """table: (M, W) int32, payload: (B, W) int32, idx: (B,), enb: (B,)."""
    m, w = table.shape
    b, _ = payload.shape
    assert b % bt == 0, (b, bt)
    grid = (b // bt,)
    return pl.pallas_call(
        functools.partial(_store_kernel, bt=bt),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # idx, enb
            grid=grid,
            in_specs=[
                pl.BlockSpec((bt, w), lambda t, *_: (t, 0)),   # payload tile
                pl.BlockSpec((m, w), lambda t, *_: (0, 0)),    # table (resident)
            ],
            out_specs=pl.BlockSpec((m, w), lambda t, *_: (0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((m, w), table.dtype),
        input_output_aliases={3: 0},  # table_in -> table_out, in-place
        interpret=interpret,
    )(idx, enb.astype(idx.dtype), payload, table)
