"""Pure-jnp oracle for the payload_store scatter (Split stage 3..N)."""
from __future__ import annotations

import jax.numpy as jnp


def payload_store_ref(table, payload, idx, enb):
    """table: (M, W) int32; payload: (B, W) int32; idx: (B,) int32;
    enb: (B,) bool.  Rows with enb=True are written at table[idx]."""
    m = table.shape[0]
    rows = jnp.where(enb, idx, m)  # out-of-bounds rows dropped
    return table.at[rows].set(payload, mode="drop")
