"""Oracle for the payload_store scatter (Split stage 3..N): the backend
registry's single jnp reference implementation (repro.backend.ref).
Dtype-polymorphic — the parity tests drive it with int32 word rows, the
core with uint8 byte rows."""
from repro.backend.ref import payload_store as payload_store_ref  # noqa: F401
