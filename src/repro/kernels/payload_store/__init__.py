from repro.kernels.payload_store.ops import payload_store  # noqa: F401
