"""Pallas payload-store kernel: Split stage 3..N (park payload rows).

Scatters parked payload prefixes into the lane-striped payload table; the
``payload_store`` primitive of the backend registry (``repro.backend``,
DESIGN.md §9), dispatched from ``core.park.split`` / ``split_fn`` and the
scanned engine (DESIGN.md §3).  See README.md here for the striping
scheme and kernel.py / ops.py for the implementation.
"""
from repro.kernels.payload_store.ops import payload_store  # noqa: F401
