from repro.kernels.maglev.ops import maglev_select  # noqa: F401
