"""Pure-jnp oracle for Maglev backend selection (must equal nf.maglev)."""
import jax.numpy as jnp

from repro.nf.maglev import _hash5


def maglev_select_ref(src_ip, dst_ip, src_port, dst_port, proto,
                      table, backend_ips):
    h = _hash5(src_ip, dst_ip, src_port, dst_port, proto)
    idx = (h % table.shape[0]).astype(jnp.int32)
    return backend_ips[table[idx]]
