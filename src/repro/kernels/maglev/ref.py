"""Oracle for Maglev backend selection: the backend registry's single jnp
reference implementation (repro.backend.ref; nf.maglev dispatches to it)."""
from repro.backend.ref import maglev_select as maglev_select_ref  # noqa: F401
