"""jit'd wrapper for the Maglev selection kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.maglev.kernel import LANES, maglev_kernel


@partial(jax.jit, static_argnames=("interpret",))
def maglev_select(src_ip, dst_ip, src_port, dst_port, proto, table,
                  backend_ips, interpret: bool = True):
    """Per-packet backend VIP selection; all inputs (B,) int32."""
    b = src_ip.shape[0]
    tile = LANES * 8
    pad = (-b) % tile

    def prep(x):
        return jnp.pad(x.astype(jnp.int32), (0, pad)).reshape(-1, LANES)

    out = maglev_kernel(
        prep(src_ip), prep(dst_ip), prep(src_port), prep(dst_port),
        prep(proto), table.astype(jnp.int32)[None, :],
        backend_ips.astype(jnp.int32)[None, :], interpret=interpret)
    return out.reshape(-1)[:b]
