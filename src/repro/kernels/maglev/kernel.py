"""Pallas TPU kernel: Maglev L4-LB backend selection (paper §6.1).

Per-packet hot path of the load balancer: hash the 5-tuple, index the Maglev
lookup table, emit the backend VIP.  The (prime-sized) lookup table and the
backend IP list stay resident in VMEM across grid steps while packet tiles
stream through.  The double gather (table -> backend id -> backend ip) is
fused into one VMEM-local pass — the TPU analogue of the paper's two chained
MAT lookups.

The hash matches repro.backend.ref.maglev_hash5 bit-exactly (int32 wrap
semantics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _maglev_kernel(sip_ref, dip_ref, sp_ref, dp_ref, proto_ref,
                   table_ref, bips_ref, out_ref, *, table_size: int):
    h = sip_ref[...]
    for ref in (dip_ref, sp_ref, dp_ref, proto_ref):
        h = h * jnp.int32(1000003) ^ ref[...]
    h = h & jnp.int32(0x7FFFFFFF)
    idx = h % table_size                      # (BT, LANES)
    table = table_ref[...][0]                 # (T,)
    bips = bips_ref[...][0]                   # (N,)
    out_ref[...] = bips[table[idx]]


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def maglev_kernel(sip, dip, sp, dp, proto, table, bips, *, bt: int = 8,
                  interpret: bool = True):
    n, lanes = sip.shape
    assert lanes == LANES and n % bt == 0
    t = table.shape[1]
    nb = bips.shape[1]
    pkt_spec = pl.BlockSpec((bt, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_maglev_kernel, table_size=t),
        grid=(n // bt,),
        in_specs=[pkt_spec] * 5 + [
            pl.BlockSpec((1, t), lambda i: (0, 0)),   # Maglev table resident
            pl.BlockSpec((1, nb), lambda i: (0, 0)),  # backend IPs resident
        ],
        out_specs=pkt_spec,
        out_shape=jax.ShapeDtypeStruct((n, LANES), jnp.int32),
        interpret=interpret,
    )(sip, dip, sp, dp, proto, table, bips)
