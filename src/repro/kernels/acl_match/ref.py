"""Oracle for the firewall ACL match kernel: the backend registry's single
jnp reference implementation (repro.backend.ref)."""
from repro.backend.ref import acl_match as acl_match_ref  # noqa: F401
