"""Pure-jnp oracle for the firewall ACL match."""
import jax.numpy as jnp


def acl_match_ref(src_ip, rules):
    """src_ip: (B,) int32; rules: (R,) int32 -> (B,) bool blocked."""
    return jnp.any(src_ip[:, None] == rules[None, :], axis=1)
