"""jit'd wrapper for the ACL match kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.acl_match.kernel import LANES, acl_match_kernel


@partial(jax.jit, static_argnames=("interpret",))
def acl_match(src_ip, rules, interpret: bool = True):
    """src_ip: (B,) int32; rules: (R,) int32 -> (B,) bool."""
    b = src_ip.shape[0]
    tile = LANES * 8
    pad = (-b) % tile
    # Pad with a sentinel that can never match a rule.
    ipp = jnp.pad(src_ip.astype(jnp.int32), (0, pad),
                  constant_values=-1).reshape(-1, LANES)
    out = acl_match_kernel(ipp, rules.astype(jnp.int32)[None, :],
                           interpret=interpret)
    return out.reshape(-1)[:b].astype(bool)
