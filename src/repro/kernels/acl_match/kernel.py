"""Pallas TPU kernel: firewall ACL linear probe (paper §6.1).

"The firewall linearly probes through a list of blocked IP addresses" — the
per-packet hot loop of the chain's first NF.  The kernel holds the (small)
rule list resident in VMEM and streams (BT, 128) packet tiles through a
broadcast-compare-reduce: every packet is checked against every rule in one
VPU pass (the literal linear probe, vectorized across lanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _acl_kernel(ip_ref, rules_ref, out_ref):
    ip = ip_ref[...]          # (BT, LANES)
    rules = rules_ref[...]    # (1, R)
    hit = (ip[:, :, None] == rules[None, :, :]).any(axis=-1)
    out_ref[...] = hit.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def acl_match_kernel(ip, rules, *, bt: int = 8, interpret: bool = True):
    """ip: (N, LANES) int32; rules: (1, R) int32 -> (N, LANES) int32 0/1."""
    n, lanes = ip.shape
    assert lanes == LANES and n % bt == 0
    r = rules.shape[1]
    return pl.pallas_call(
        _acl_kernel,
        grid=(n // bt,),
        in_specs=[
            pl.BlockSpec((bt, LANES), lambda t: (t, 0)),
            pl.BlockSpec((1, r), lambda t: (0, 0)),  # rules resident
        ],
        out_specs=pl.BlockSpec((bt, LANES), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((n, LANES), jnp.int32),
        interpret=interpret,
    )(ip, rules)
