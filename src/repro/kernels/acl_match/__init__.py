from repro.kernels.acl_match.ops import acl_match  # noqa: F401
