"""jit'd public wrapper for payload_fetch (see payload_store.ops for the
byte/word layout rationale)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.payload_fetch.kernel import payload_fetch_kernel
from repro.kernels.payload_store.ops import _pad_lanes, _to_bytes, _to_words


@partial(jax.jit, static_argnames=("interpret",))
def payload_fetch(table_u8, idx, mask, interpret: bool = True):
    """Gather+clear parked rows.  Returns (parked (B, bytes) u8, new table)."""
    m, nbytes = table_u8.shape
    assert nbytes % 4 == 0, nbytes
    b = idx.shape[0]
    tw = _pad_lanes(_to_words(table_u8))
    bt = 8 if b % 8 == 0 else 1
    gathered, new_table = payload_fetch_kernel(
        tw, idx.astype(jnp.int32), mask, bt=bt, interpret=interpret)
    return (
        _to_bytes(gathered[:, : nbytes // 4], nbytes),
        _to_bytes(new_table[:, : nbytes // 4], nbytes),
    )
