from repro.kernels.payload_fetch.ops import payload_fetch  # noqa: F401
