"""Pallas payload-fetch kernel: Merge stage 3..N (gather + clear rows).

Gathers parked payload rows for returning packets and zeroes their slots;
the ``payload_fetch`` primitive of the backend registry (``repro.backend``,
DESIGN.md §9), dispatched from ``core.park.merge`` / ``merge_fn`` and the
scanned engine (DESIGN.md §3).  See README.md here for the striping
scheme and kernel.py / ops.py for the implementation.
"""
from repro.kernels.payload_fetch.ops import payload_fetch  # noqa: F401
