"""Pallas payload-fetch kernel: Merge stage 3..N (gather + clear rows).

Gathers parked payload rows for returning packets and zeroes their slots;
the ``use_kernel=True`` data path of ``core.park.merge`` / ``merge_fn`` and
of the scanned engine (DESIGN.md §3).  See README.md here for the striping
scheme and kernel.py / ops.py for the implementation.
"""
from repro.kernels.payload_fetch.ops import payload_fetch  # noqa: F401
