"""Oracle for the payload_fetch gather+clear (Merge stage 3..N): the
backend registry's single jnp reference implementation
(repro.backend.ref)."""
from repro.backend.ref import payload_fetch as payload_fetch_ref  # noqa: F401
