"""Pure-jnp oracle for the payload_fetch gather+clear (Merge stage 3..N)."""
from __future__ import annotations

import jax.numpy as jnp


def payload_fetch_ref(table, idx, mask):
    """table: (M, W) int32; idx: (B,); mask: (B,) bool.
    Returns (gathered (B, W) with unmatched rows zeroed, new table with
    matched rows cleared) — Alg. 2 lines 21-23."""
    m = table.shape[0]
    gathered = jnp.where(mask[:, None], table[idx], 0)
    rows = jnp.where(mask, idx, m)
    cleared = table.at[rows].set(0, mode="drop")
    return gathered, cleared
