"""Pallas TPU kernel: payload gather + slot clear (paper Alg. 2 stage 3..N).

Merge's data plane: for each returning packet whose tag validated, read the
parked payload row at ``idx`` and zero it ("hdr.pload_block[idx] =
pload_tbl[meta.tbl_idx]; pload_tbl[meta.tbl_idx] = 0", Alg. 2 lines 21-23).
Per packet this is exactly two stateful accesses to the same row — read then
clear — honouring the Tofino one-access-per-stage budget by splitting across
two logical stages; in the TPU kernel both touch the same resident VMEM block
so the clear is free of extra HBM traffic.

Unmatched packets (premature eviction / ENB=0) produce zero rows and leave
the table untouched (predicated, branch-free — P4-style).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BT = 8


def _fetch_kernel(idx_ref, mask_ref, table_in_ref, out_ref, table_ref, *,
                  bt: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _():
        table_ref[...] = table_in_ref[...]

    for i in range(bt):
        b = t * bt + i
        row = idx_ref[b]
        live = mask_ref[b] != 0
        # gather (predicated to zero for unmatched packets)
        val = table_ref[pl.ds(row, 1), :]
        out_ref[pl.ds(i, 1), :] = jnp.where(live, val, 0)

        # clear the slot
        @pl.when(live)
        def _():
            table_ref[pl.ds(row, 1), :] = jnp.zeros_like(val)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def payload_fetch_kernel(table, idx, mask, *, bt: int = DEFAULT_BT,
                         interpret: bool = True):
    """table: (M, W) int32; idx/mask: (B,).  Returns (gathered, new_table)."""
    m, w = table.shape
    b = idx.shape[0]
    assert b % bt == 0, (b, bt)
    grid = (b // bt,)
    return pl.pallas_call(
        functools.partial(_fetch_kernel, bt=bt),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # idx, mask
            grid=grid,
            in_specs=[
                pl.BlockSpec((m, w), lambda t, *_: (0, 0)),  # table (resident)
            ],
            out_specs=[
                pl.BlockSpec((bt, w), lambda t, *_: (t, 0)),  # gathered tile
                pl.BlockSpec((m, w), lambda t, *_: (0, 0)),   # table out
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, w), table.dtype),
            jax.ShapeDtypeStruct((m, w), table.dtype),
        ],
        input_output_aliases={2: 1},  # table -> table out
        interpret=interpret,
    )(idx, mask.astype(idx.dtype), table)
