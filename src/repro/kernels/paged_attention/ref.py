"""Pure-jnp oracle for paged decode attention: gather pages, dense attend."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def paged_decode_attention_ref(q, k_pages, v_pages, page_table, lengths):
    """q: (B, K, G, E); k_pages/v_pages: (P, page, K, E);
    page_table: (B, MP) int32 (-1 pad); lengths: (B,).
    Returns (B, K, G, E)."""
    b, kh, g, e = q.shape
    page = k_pages.shape[1]
    mp = page_table.shape[1]
    pt = jnp.maximum(page_table, 0)
    k = k_pages[pt].reshape(b, mp * page, kh, e)       # (B, T, K, E)
    v = v_pages[pt].reshape(b, mp * page, kh, e)
    s = jnp.einsum("bkge,btke->bkgt", q, k,
                   preferred_element_type=jnp.float32) * (e ** -0.5)
    pos = jnp.arange(mp * page)[None, :]
    mask = (pos < lengths[:, None]) & (page_table >= 0).repeat(page, axis=1)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    norm = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgt,btke->bkge", (p / norm).astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
