"""Pallas TPU kernel: paged decode attention over the parked-KV pool.

The serving-side Merge: parked payload pages are gathered *by tag* straight
from the pool while attention runs — the page table (the request header's
tag list) rides in scalar prefetch and drives the BlockSpec index_map, so
each grid step DMAs exactly one (page_tokens, K, E) KV page from the pool
into VMEM.  This is the canonical TPU paged-attention structure:

  grid = (B, MAX_PAGES);  k/v page blocks indexed by page_table[b, p];
  flash running (m, l, acc) in VMEM scratch, persisted across the page axis;
  the output block for request b is written on its last page step.

Pool pages never move in HBM (they are "parked"); only the 8-byte-per-page
header crossed the network to get here — the paper's goodput argument,
realized as a collective-bytes reduction (see benchmarks/bench_parking.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, out_ref,
                  m_ref, l_ref, acc_ref, *, page: int, max_pages: int):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # (K, G, E)
    k = k_ref[0]                       # (page, K, E)
    v = v_ref[0]
    e = q.shape[-1]

    s = jnp.einsum("kge,tke->kgt", q, k,
                   preferred_element_type=jnp.float32) * (e ** -0.5)
    tok = p * page + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
    live = (tok < len_ref[b]) & (pt_ref[b, p] >= 0)
    s = jnp.where(live, s, NEG_INF)

    m_prev = m_ref[...]                # (K, G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + pexp.sum(axis=-1, keepdims=True)
    pv = jnp.einsum("kgt,tke->kge", pexp.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new

    @pl.when(p == max_pages - 1)
    def _():
        out_ref[0] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_kernel(q, k_pages, v_pages, page_table, lengths,
                                  *, interpret: bool = True):
    """q: (B,K,G,E); k_pages/v_pages: (P, page, K, E);
    page_table: (B, MP) int32 (-1 pad); lengths: (B,) int32."""
    b, kh, g, e = q.shape
    npages, page, _, _ = k_pages.shape
    mp = page_table.shape[1]

    kv_spec = pl.BlockSpec(
        (1, page, kh, e), lambda b_, p_, pt_, ln_: (pt_[b_, p_], 0, 0, 0))
    return pl.pallas_call(
        functools.partial(_paged_kernel, page=page, max_pages=mp),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # page_table (orig, with -1), lengths
            grid=(b, mp),
            in_specs=[
                pl.BlockSpec((1, kh, g, e), lambda b_, p_, pt_, ln_: (b_, 0, 0, 0)),
                kv_spec,
                kv_spec,
            ],
            out_specs=pl.BlockSpec(
                (1, kh, g, e), lambda b_, p_, pt_, ln_: (b_, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((kh, g, 1), jnp.float32),
                pltpu.VMEM((kh, g, 1), jnp.float32),
                pltpu.VMEM((kh, g, e), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, e), q.dtype),
        interpret=interpret,
    )(page_table, lengths, q, k_pages, v_pages)
