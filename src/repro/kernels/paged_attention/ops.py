"""jit'd wrapper for paged decode attention."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_decode_attention_kernel


@partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pages, v_pages, page_table, lengths,
                           interpret: bool = True):
    """q: (B, K, G, E); k_pages/v_pages: (P, page, K, E);
    page_table: (B, MP) int32 with -1 padding; lengths: (B,) int32."""
    return paged_decode_attention_kernel(
        q, k_pages, v_pages, page_table.astype(jnp.int32),
        lengths.astype(jnp.int32), interpret=interpret)
