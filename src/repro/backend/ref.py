"""The single jnp reference implementation of every dataplane primitive.

One function per registry primitive (DESIGN.md §9) — THE reference
semantics of the repo's per-packet hot path.  ``core/header``,
``nf/firewall``, ``nf/maglev`` and ``core/park`` all delegate here through
``repro.backend.registry.dispatch``; each Pallas kernel under
``repro.kernels`` must match its primitive bit-exactly (asserted by
``tests/test_backend.py`` and ``tests/test_kernels.py``).  No other module
may duplicate this math.

Everything here is shape-polymorphic over a leading batch axis and written
branch-free (P4-style predication, paper §2) so it composes inside
``lax.scan``/``vmap`` exactly like the kernels do.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# crc16_tag — PayloadPark header tag CRC (paper §3.2, Fig. 2)
# ---------------------------------------------------------------------------

CRC_POLY = 0x1021
CRC_INIT = 0xFFFF


def crc16_bytes(data: jax.Array) -> jax.Array:
    """CRC-16/CCITT-FALSE over the trailing axis of a uint8/int32 byte array.

    ``data``: (..., N) byte values in [0, 255].  Returns (...,) int32 CRC.
    Bitwise, branch-free formulation (P4 actions are short VLIW programs —
    the same constraint shapes the Pallas kernel).
    """
    data = data.astype(jnp.int32)
    n = data.shape[-1]
    crc = jnp.full(data.shape[:-1], CRC_INIT, jnp.int32)

    def per_byte(i, crc):
        crc = crc ^ (data[..., i] << 8)

        def per_bit(_, c):
            hi = (c >> 15) & 1
            c = (c << 1) & 0xFFFF
            return jnp.where(hi == 1, c ^ CRC_POLY, c)

        return jax.lax.fori_loop(0, 8, per_bit, crc)

    return jax.lax.fori_loop(0, n, per_byte, crc)


def tag_bytes(ti: jax.Array, clk: jax.Array) -> jax.Array:
    """Pack (ti, clk) into 4 little-endian bytes: (..., 4) int32."""
    ti = ti.astype(jnp.int32)
    clk = clk.astype(jnp.int32)
    return jnp.stack(
        [ti & 0xFF, (ti >> 8) & 0xFF, clk & 0xFF, (clk >> 8) & 0xFF], axis=-1
    )


def crc16_tag(ti: jax.Array, clk: jax.Array) -> jax.Array:
    """CRC over the PayloadPark tag; mirrored by repro.kernels.crc16."""
    return crc16_bytes(tag_bytes(ti, clk))


# ---------------------------------------------------------------------------
# acl_match — firewall blocked-IP linear probe (paper §6.1)
# ---------------------------------------------------------------------------

def acl_match(src_ip: jax.Array, rules: jax.Array) -> jax.Array:
    """src_ip: (B,) int32; rules: (R,) int32 -> (B,) bool blocked."""
    return jnp.any(src_ip[:, None] == rules[None, :], axis=1)


# ---------------------------------------------------------------------------
# maglev_select — L4-LB backend selection (paper §6.1, Maglev NSDI'16)
# ---------------------------------------------------------------------------

def maglev_hash5(src_ip, dst_ip, src_port, dst_port, proto) -> jax.Array:
    """int32 5-tuple hash (wraps like uint32); mirrored bit-exactly by the
    Pallas kernel in repro.kernels.maglev."""
    h = src_ip.astype(jnp.int32)
    for v in (dst_ip, src_port, dst_port, proto):
        h = h * jnp.int32(1000003) ^ v.astype(jnp.int32)
    return h & jnp.int32(0x7FFFFFFF)


def maglev_select(src_ip, dst_ip, src_port, dst_port, proto,
                  table, backend_ips) -> jax.Array:
    """Hash the 5-tuple, index the Maglev lookup table, return the backend
    VIP per packet: all packet fields (B,) int32 -> (B,) int32."""
    h = maglev_hash5(src_ip, dst_ip, src_port, dst_port, proto)
    idx = (h % table.shape[0]).astype(jnp.int32)
    return backend_ips[table[idx]]


# ---------------------------------------------------------------------------
# payload_store / payload_fetch — parked-payload movement (paper Fig. 4)
# ---------------------------------------------------------------------------

def payload_store(table, payload, idx, enb) -> jax.Array:
    """Split stage 3..N scatter: ``table[idx[b]] = payload[b]`` where
    ``enb[b]``.  table: (M, W); payload: (B, W); idx: (B,) int32;
    enb: (B,) bool.  Dtype-polymorphic: the core uses byte rows (uint8),
    the kernel parity tests word rows (int32)."""
    m = table.shape[0]
    rows = jnp.where(enb, idx, m)  # out-of-bounds rows dropped
    return table.at[rows].set(payload, mode="drop")


def payload_fetch(table, idx, mask):
    """Merge stage 3..N gather+clear (Alg. 2 lines 21-23).  Returns
    (gathered (B, W) with unmatched rows zeroed, table with matched rows
    cleared)."""
    m = table.shape[0]
    gathered = jnp.where(mask[:, None], table[idx], 0)
    rows = jnp.where(mask, idx, m)
    cleared = table.at[rows].set(jnp.zeros_like(gathered), mode="drop")
    return gathered, cleared
