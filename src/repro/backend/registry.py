"""The dataplane-primitive registry: one ref + one Pallas impl per primitive.

``dispatch(name, backend)`` is the single switch every hot-path call site
goes through (DESIGN.md §9): ``core/header.crc16_tag``/``tag_valid``,
``Firewall.__call__``, ``MaglevLB.__call__`` and ``core/park``'s payload
movement.  The returned callable is resolved at trace time from the frozen
``BackendConfig``, so jitted programs specialize on the backend exactly as
they specialize on shapes.

The Pallas implementations are imported lazily (inside the wrapper
functions): pure-ref runs never import the kernel layer, and the kernels
are free to import ``repro.backend.ref`` for shared constants without an
import cycle.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

from repro.backend import ref as R
from repro.backend.config import PRIMITIVES, as_config


@dataclasses.dataclass(frozen=True)
class Primitive:
    """One registry entry.  ``pallas`` takes the ref signature plus a
    keyword-only ``interpret`` flag (the two Pallas modes share a body)."""

    name: str
    ref: Callable
    pallas: Callable


def _pallas_crc16_tag(ti, clk, *, interpret: bool = True):
    from repro.kernels.crc16.ops import crc16_tag_kernel_op
    return crc16_tag_kernel_op(ti, clk, interpret=interpret)


def _pallas_acl_match(src_ip, rules, *, interpret: bool = True):
    from repro.kernels.acl_match.ops import acl_match
    return acl_match(src_ip, rules, interpret=interpret)


def _pallas_maglev_select(src_ip, dst_ip, src_port, dst_port, proto,
                          table, backend_ips, *, interpret: bool = True):
    from repro.kernels.maglev.ops import maglev_select
    return maglev_select(src_ip, dst_ip, src_port, dst_port, proto,
                         table, backend_ips, interpret=interpret)


def _pallas_payload_store(table, payload, idx, enb, *,
                          interpret: bool = True):
    from repro.kernels.payload_store.ops import payload_store
    return payload_store(table, payload, idx, enb, interpret=interpret)


def _pallas_payload_fetch(table, idx, mask, *, interpret: bool = True):
    from repro.kernels.payload_fetch.ops import payload_fetch
    return payload_fetch(table, idx, mask, interpret=interpret)


_REGISTRY: dict[str, Primitive] = {
    p.name: p for p in (
        Primitive("crc16_tag", R.crc16_tag, _pallas_crc16_tag),
        Primitive("acl_match", R.acl_match, _pallas_acl_match),
        Primitive("maglev_select", R.maglev_select, _pallas_maglev_select),
        Primitive("payload_store", R.payload_store, _pallas_payload_store),
        Primitive("payload_fetch", R.payload_fetch, _pallas_payload_fetch),
    )
}

assert tuple(_REGISTRY) == PRIMITIVES, (tuple(_REGISTRY), PRIMITIVES)


def primitive(name: str) -> Primitive:
    if name not in _REGISTRY:
        raise KeyError(f"unknown primitive {name!r} (have {PRIMITIVES})")
    return _REGISTRY[name]


def dispatch(name: str,
             backend: "BackendConfig | str | None" = None) -> Callable:
    """Resolve one primitive to the callable its backend selects."""
    prim = primitive(name)
    mode = as_config(backend).resolve(name)
    if mode == "ref":
        return prim.ref
    return partial(prim.pallas, interpret=(mode == "pallas_interpret"))
