"""Unified dataplane-backend layer (DESIGN.md §9).

One registry of per-packet hot-path primitives (``crc16_tag``,
``acl_match``, ``maglev_select``, ``payload_store``, ``payload_fetch``),
each with exactly one jnp reference implementation (``ref``) and one
Pallas implementation (``repro.kernels``), selected by a frozen
``BackendConfig`` threaded through ``core.park``, the NF chain, the
simulation engine and the scenario matrix.
"""
from repro.backend.config import (BACKENDS, PRIMITIVES, BackendConfig,
                                  as_config, auto_backend, coerce_backend)
from repro.backend.registry import Primitive, dispatch, primitive

__all__ = [
    "BACKENDS", "PRIMITIVES", "BackendConfig", "as_config", "auto_backend",
    "coerce_backend", "Primitive", "dispatch", "primitive",
]
