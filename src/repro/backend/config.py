"""Frozen backend selection for the dataplane-primitive registry.

A ``BackendConfig`` names which implementation of each hot-path primitive
(DESIGN.md §9) the dataplane runs:

  * ``"ref"``              — the single jnp reference implementation
                             (``repro.backend.ref``);
  * ``"pallas"``           — the Pallas TPU kernel, compiled;
  * ``"pallas_interpret"`` — the same kernel body under ``interpret=True``
                             (bit-exact validation path, runs on CPU);
  * ``"auto"``             — resolve per platform: Pallas on TPU, ref
                             everywhere else.

It is a frozen, hashable value by design: it rides in ``jax.jit`` static
arguments (``core.park.split``/``merge``/``recirc``), in the engine's
``lru_cache`` compile key (``switchsim.engine._compiled``) and in the
scenario runner's ``compile_key`` — two runs with equal configs share a
compiled program.  ``overrides`` selects a different backend for individual
primitives (e.g. Pallas payload movement with ref CRC).

``coerce_backend`` normalizes the three accepted spellings (None, a backend
name, a ``BackendConfig``) into the canonical platform-resolved form every
dataplane entry point compiles against.  (The boolean kernel-toggle kwarg it
once funnelled had its deprecation cycle in PR 5 and is gone: passing it
anywhere is now a ``TypeError``.)
"""
from __future__ import annotations

import dataclasses

# The registry (repro.backend.registry) asserts it implements exactly this
# set; the names live here so BackendConfig can validate overrides without
# importing the kernel layer.
PRIMITIVES = ("crc16_tag", "acl_match", "maglev_select", "payload_store",
              "payload_fetch")

BACKENDS = ("ref", "pallas", "pallas_interpret", "auto")


def auto_backend() -> str:
    """What ``"auto"`` resolves to on this process's default device."""
    import jax
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@dataclasses.dataclass(frozen=True)
class BackendConfig:
    """Backend selection: one default plus per-primitive overrides.

    ``overrides`` is stored as a sorted tuple of ``(primitive, backend)``
    pairs (a dict is accepted and normalized) so equal selections hash
    equally regardless of construction order.
    """

    default: str = "auto"
    overrides: tuple[tuple[str, str], ...] = ()

    def __post_init__(self):
        if isinstance(self.overrides, dict):
            object.__setattr__(self, "overrides",
                               tuple(sorted(self.overrides.items())))
        if self.default not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.default!r} (have {BACKENDS})")
        for prim, mode in self.overrides:
            if prim not in PRIMITIVES:
                raise ValueError(
                    f"override for unknown primitive {prim!r} "
                    f"(have {PRIMITIVES})")
            if mode not in BACKENDS:
                raise ValueError(
                    f"unknown backend {mode!r} for {prim!r} "
                    f"(have {BACKENDS})")

    def resolve(self, primitive: str) -> str:
        """Concrete backend ("ref" | "pallas" | "pallas_interpret") for one
        primitive, with ``"auto"`` resolved against the runtime platform."""
        if primitive not in PRIMITIVES:
            raise KeyError(
                f"unknown primitive {primitive!r} (have {PRIMITIVES})")
        mode = dict(self.overrides).get(primitive, self.default)
        return auto_backend() if mode == "auto" else mode

    def concrete(self) -> "BackendConfig":
        """Canonical platform-resolved form: no ``"auto"`` left, redundant
        overrides dropped.  Used as the compile-cache key so ``"auto"`` and
        its resolution share one compiled program."""
        default = (auto_backend() if self.default == "auto" else self.default)
        overrides = tuple(sorted(
            (p, m) for p, m in ((p, self.resolve(p)) for p in PRIMITIVES)
            if m != default))
        return BackendConfig(default, overrides)


def as_config(backend: "BackendConfig | str | None") -> BackendConfig:
    """Accept the three spellings every dataplane entry point takes:
    None (= auto), a backend name, or a full BackendConfig."""
    if backend is None:
        return BackendConfig()
    if isinstance(backend, BackendConfig):
        return backend
    if isinstance(backend, str):
        return BackendConfig(default=backend)
    raise TypeError(
        f"backend must be a BackendConfig, a backend name or None; "
        f"got {type(backend).__name__}")


def coerce_backend(backend: "BackendConfig | str | None" = None) -> BackendConfig:
    """Validate ``backend`` and resolve it into one concrete BackendConfig
    (the canonical compile-cache key form; see ``concrete``)."""
    return as_config(backend).concrete()
