"""Exact HLO accounting for the roofline: per-layer (x per-chunk) probes.

XLA's cost analysis counts while-loop bodies exactly ONCE (verified
empirically — see EXPERIMENTS.md §Dry-run), so a scan-over-layers model would
under-report FLOPs/bytes/collective-bytes by ~the layer count.  We therefore
compile, for each cell, a set of small *probe* models with all scans unrolled
(identical math, Python loops), under the SAME mesh and sharding rules, and
extrapolate.

Attention-family archs: probes at 1 and 2 layers per scalable segment group,
full sequence (attention cost is quadratic in S, so S must stay authentic;
unrolled blockwise attention at 4096-token blocks keeps the op count small):

    metric(full) = metric(base) + sum_g (metric(bump_g) - metric(base))
                                   * (count_g - 1)

SSM family (mamba2): every cost is LINEAR in sequence length (that is the
point of SSD), but the chunk scan would unroll to S/Q steps at full S.  So
probes run at S = Q and S = 2Q tokens with a bilinear model over
(layers L, chunks C):

    m(L, C) = m11 + (m21-m11)(L-1) + (m12-m11)(C-1)
                  + (m22-m21-m12+m11)(L-1)(C-1)

which is exact for homogeneous layers x homogeneous chunks.

Both are exact because the model is built from homogeneous segments — every
layer (and every chunk) lowers to identical HLO.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig


@dataclasses.dataclass(frozen=True)
class Probe:
    name: str
    cfg: ModelConfig
    shape: ShapeConfig   # possibly seq-reduced (ssm probes)


def _combine_linear(extra: dict[str, int]) -> Callable:
    def combine(costs: dict[str, dict]) -> dict:
        base = costs["base"]
        out = dict(base)
        for key, v in base.items():
            if not isinstance(v, (int, float)) or v is None:
                continue
            total = float(v)
            for g, n in extra.items():
                bv = costs[g].get(key)
                if bv is not None:
                    total += (float(bv) - float(v)) * n
            out[key] = total
        return out
    return combine


def _combine_bilinear(l_full: int, c_full: int) -> Callable:
    def combine(costs: dict[str, dict]) -> dict:
        m11, m21 = costs["l1c1"], costs["l2c1"]
        m12, m22 = costs["l1c2"], costs["l2c2"]
        out = dict(m11)
        for key, v in m11.items():
            if not isinstance(v, (int, float)) or v is None:
                continue
            a = float(v)
            b = float(m21[key]) - a
            c = float(m12[key]) - a
            d = float(m22[key]) - float(m21[key]) - float(m12[key]) + a
            out[key] = (a + b * (l_full - 1) + c * (c_full - 1)
                        + d * (l_full - 1) * (c_full - 1))
        return out
    return combine


def probe_plan(cfg: ModelConfig, shape: ShapeConfig
               ) -> tuple[list[Probe], Callable]:
    rep = dataclasses.replace
    if cfg.family == "ssm" and shape.kind in ("train", "prefill"):
        q = cfg.ssm.chunk
        s1 = rep(shape, seq_len=q)
        s2 = rep(shape, seq_len=2 * q)
        l1 = rep(cfg, num_layers=1)
        l2 = rep(cfg, num_layers=2)
        probes = [
            Probe("l1c1", l1, s1), Probe("l2c1", l2, s1),
            Probe("l1c2", l1, s2), Probe("l2c2", l2, s2),
        ]
        return probes, _combine_bilinear(cfg.num_layers, shape.seq_len // q)

    if cfg.family == "audio":
        base = rep(cfg, num_layers=1, enc_layers=1)
        probes = [
            Probe("base", base, shape),
            Probe("enc", rep(cfg, num_layers=1, enc_layers=2), shape),
            Probe("dec", rep(cfg, num_layers=2, enc_layers=1), shape),
        ]
        return probes, _combine_linear(
            {"enc": cfg.enc_layers - 1, "dec": cfg.num_layers - 1})
    if cfg.family == "hybrid":
        pat = len(cfg.hybrid.pattern)
        full, remlayers = divmod(cfg.num_layers, pat)
        base_layers = pat + remlayers          # 1 superblock + tail
        probes = [
            Probe("base", rep(cfg, num_layers=base_layers), shape),
            Probe("sb", rep(cfg, num_layers=base_layers + pat), shape),
        ]
        return probes, _combine_linear({"sb": full - 1})
    if cfg.moe is not None and cfg.moe.first_dense_layers:
        fd = cfg.moe.first_dense_layers
        probes = [
            Probe("base", rep(cfg, num_layers=fd + 1), shape),
            Probe("blocks", rep(cfg, num_layers=fd + 2), shape),
        ]
        return probes, _combine_linear({"blocks": cfg.num_layers - fd - 1})
    probes = [
        Probe("base", rep(cfg, num_layers=1), shape),
        Probe("blocks", rep(cfg, num_layers=2), shape),
    ]
    return probes, _combine_linear({"blocks": cfg.num_layers - 1})


def accounting_blocks(seq_len: int) -> tuple[int, int]:
    """Large attention blocks for the unrolled probes: identical FLOPs,
    far fewer unrolled iterations."""
    blk = min(seq_len, 4096)
    return blk, blk
