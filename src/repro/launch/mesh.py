"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first jax
init, and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips, ("data", "model").
    Multi-pod: 2x16x16 = 512 chips, ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 2, data: int = 2, pod: int = 1):
    """Small mesh over forced-host devices for distributed unit tests."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
