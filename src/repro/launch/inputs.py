"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns the abstract args for the step function a
given (arch x shape) cell lowers:
  train_*   -> (train_state, batch)        for train_step
  prefill_* -> (params, batch)             for prefill
  decode_*/long_* -> (params, cache, tokens, positions) for decode_step

Modality frontends are STUBS per the assignment brief: the vlm cell's batch
carries precomputed patch embeddings (B, NV, D); the audio cell's batch
carries precomputed frames (B, S, D).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig
from repro.models.common import DTYPE
from repro.models.lm import LM

VLM_PATCH_TOKENS = 256


def sds(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((b, s)), "labels": sds((b, s))}
    if cfg.family == "vlm":
        batch["positions"] = sds((3, b, s))
        batch["vision_embeds"] = sds((b, VLM_PATCH_TOKENS, cfg.d_model), DTYPE)
    if cfg.enc_layers:
        batch["enc_frames"] = sds((b, s, cfg.d_model), DTYPE)
    return batch


def prefill_batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    batch = train_batch_struct(cfg, shape)
    del batch["labels"]
    return batch


def train_state_struct(lm: LM):
    from repro.training.train_step import init_train_state
    return jax.eval_shape(lambda: init_train_state(lm, jax.random.key(0)))


def params_struct(lm: LM):
    return jax.eval_shape(lambda: lm.init_params(jax.random.key(0)))


def decode_inputs_struct(lm: LM, shape: ShapeConfig):
    cfg = lm.cfg
    b, s = shape.global_batch, shape.seq_len
    cache = lm.cache_struct(b, s, enc_len=s if cfg.enc_layers else 0)
    tokens = sds((b,))
    positions = sds((b,))
    return params_struct(lm), cache, tokens, positions


def input_specs(lm: LM, shape: ShapeConfig):
    """The abstract argument tuple for the cell's step function."""
    cfg = lm.cfg
    if shape.kind == "train":
        return (train_state_struct(lm), train_batch_struct(cfg, shape))
    if shape.kind == "prefill":
        return (params_struct(lm), prefill_batch_struct(cfg, shape))
    return decode_inputs_struct(lm, shape)
