"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh, resolves sharding rules,
lowers the cell's step function against ShapeDtypeStruct inputs, compiles it,
and records:
  * memory_analysis()   — proves the cell fits per-device HBM,
  * cost_analysis()     — HLO FLOPs / bytes for the roofline,
  * collective bytes    — parsed from the post-SPMD compiled HLO text
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute result sizes x ring factors).

Results go to benchmarks/dryrun_results/<cell>.json; benchmarks/roofline.py
turns them into the EXPERIMENTS.md tables.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import os
import re
import time
import traceback

import jax
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs.shapes import SHAPES, applicable
from repro.distributed import force_host_devices
from repro.distributed.sharding import Rules
from repro.launch import inputs as inp
from repro.launch.accounting import accounting_blocks, probe_plan
from repro.launch.mesh import make_production_mesh
from repro.models.lm import LM
from repro.training.train_step import TrainConfig, train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/dryrun_results")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(.+?)\s(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(-start|-done)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|"
                       r"pred|c64|c128)\[([\d,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+(?:,\d+)*)\]<=")


def _bytes_of_shapes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        dims = [int(d) for d in m.group(1).split(",")]
        n = 1
        for d in dims[1:]:
            n *= d
        return max(n, 1)
    return 2


_ENTRY_OP_RE = re.compile(
    r"=\s*\(?([a-z0-9]+)\[([\d,]*)\][^=]*?\s([a-z][\w-]*)\(")


def entry_op_bytes(hlo_text: str) -> dict:
    """Top-level (entry computation) result bytes by opcode.

    Approximates real buffer traffic far better than cost_analysis's
    'bytes accessed' on the CPU backend, which also counts fusion-internal
    reads and the f32 upcasts CPU inserts around bf16 dots (TPU executes
    bf16 natively) — see EXPERIMENTS.md §Perf for the comparison.
    """
    hist: dict[str, float] = {}
    in_entry = False
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY "):
            in_entry = True
            continue
        if in_entry and line.startswith("}"):
            break
        if not in_entry:
            continue
        m = _ENTRY_OP_RE.search(line)
        if not m:
            continue
        dt, dims, op = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        hist[op] = hist.get(op, 0.0) + n * _DTYPE_BYTES[dt]
    return hist


def collective_stats(hlo_text: str) -> dict:
    """Per-op-kind byte totals from a post-SPMD HLO module.

    Per-device ring-model wire factors on the op's *result* bytes:
      all-gather / all-to-all: (n-1)/n  (result is the full gathered array),
      reduce-scatter: (n-1)            (result is the 1/n shard),
      all-reduce: 2(n-1)/n (reduce-scatter + all-gather phases),
      collective-permute: 1.
    ``n`` parsed from replica_groups (list or iota form).
    """
    stats = {k: {"count": 0, "bytes": 0.0} for k in
             ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute")}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind, suffix = m.group(2), m.group(3)
        if suffix == "-done":
            continue  # async pair: count the -start only
        size = _bytes_of_shapes(m.group(1))
        n = _group_size(line)
        factor = {"all-gather": (n - 1) / n,
                  "reduce-scatter": float(n - 1),
                  "all-reduce": 2 * (n - 1) / n,
                  "all-to-all": (n - 1) / n,
                  "collective-permute": 1.0}[kind]
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += size * factor
    stats["total_bytes"] = sum(
        v["bytes"] for k, v in stats.items() if isinstance(v, dict))
    return stats


def build_step(lm: LM, shape, rules: Rules):
    """Returns (fn, in_shardings, out_shardings, donate) for the cell."""
    shard = rules.act_shard()
    if shape.kind == "train":
        tcfg = TrainConfig()

        def fn(state, batch):
            return train_step(lm, tcfg, state, batch, shard=shard)

        state_struct, batch_struct = inp.input_specs(lm, shape)
        state_sh = rules.to_shardings(rules.state_spec(state_struct))
        batch_sh = rules.to_shardings(rules.batch_spec(batch_struct))
        return fn, (state_sh, batch_sh), (state_sh, None), (0,)

    if shape.kind == "prefill":
        def fn(params, batch):
            return lm.prefill(params, batch, cache_len=shape.seq_len,
                              shard=shard)

        params_struct, batch_struct = inp.input_specs(lm, shape)
        p_sh = rules.to_shardings(rules.param_specs(params_struct))
        b_sh = rules.to_shardings(rules.batch_spec(batch_struct))
        return fn, (p_sh, b_sh), None, ()

    def fn(params, cache, tokens, positions):
        return lm.decode_step(params, cache, tokens, positions, shard=shard)

    params_struct, cache_struct, tok, pos = inp.input_specs(lm, shape)
    p_sh = rules.to_shardings(rules.param_specs(params_struct))
    c_sh = rules.to_shardings(rules.cache_spec(cache_struct))
    tok_sh = rules.named(P(rules._dp_for(tok.shape[0])))
    return fn, (p_sh, c_sh, tok_sh, tok_sh), (None, c_sh), (1,)


def _compile_once(lm: LM, shape, mesh, rules: Rules):
    """Lower + compile one step function.  Returns (compiled, metrics dict)."""
    with mesh:
        fn, in_sh, out_sh, donate = build_step(lm, shape, rules)
        args = inp.input_specs(lm, shape)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll = collective_stats(compiled.as_text())
    ob = entry_op_bytes(compiled.as_text())
    flat = {
        "flops": cost.get("flops"),
        "bytes_accessed": cost.get("bytes accessed"),
        "entry_bytes": sum(ob.values()),
        "transcendentals": cost.get("transcendentals"),
        "coll_total_bytes": coll["total_bytes"],
    }
    for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute"):
        flat[f"coll_{k}_bytes"] = coll[k]["bytes"]
        flat[f"coll_{k}_count"] = coll[k]["count"]
    memd = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                        None),
    }
    return flat, memd


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: str = RESULTS_DIR, verbose: bool = True,
             rules_overrides: dict | None = None,
             lm_overrides: dict | None = None,
             tag: str = "") -> dict:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    cell = f"{arch}__{shape_name}__{mesh_kind}" + (f"__{tag}" if tag else "")
    if not ok:
        rec = {"cell": cell, "status": "skipped", "reason": why}
        _write(out_dir, cell, rec)
        if verbose:
            _print_cell(rec)
        return rec

    multi = mesh_kind == "multipod"
    mesh = make_production_mesh(multi_pod=multi)
    lm_kw = lm_overrides or {}
    # default sharding policy per shape kind: training activations are
    # sequence-sharded (Megatron SP) so per-layer residuals fit HBM
    rkw = {"sp_activations": shape.kind == "train"}
    rkw.update(rules_overrides or {})
    # wall-clock here times the compile itself and is reported, never fed
    # into program logic — exempt from RPL003 via the replint baseline
    t0 = time.time()
    try:
        # 1. full-config compile: proves sharding coherence + memory fit
        lm = LM(cfg, **lm_kw)
        rules = Rules(cfg, mesh, **rkw)
        full_cost, memd = _compile_once(lm, shape, mesh, rules)
        t_full = time.time() - t0

        # 2. accounting probes: unrolled small models -> exact per-layer cost
        probes, combine = probe_plan(cfg, shape)
        probe_cost: dict[str, dict] = {}
        for pr in probes:
            plm = LM(pr.cfg, unroll=True,
                     attn_blocks=accounting_blocks(pr.shape.seq_len), **lm_kw)
            prules = Rules(pr.cfg, mesh, **rkw)
            probe_cost[pr.name], _ = _compile_once(plm, pr.shape, mesh,
                                                   prules)
        exact = combine(probe_cost)
        t_probe = time.time() - t0 - t_full

        rec = {
            "cell": cell,
            "status": "ok",
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "devices": int(len(mesh.devices.reshape(-1))),
            "compile_s": round(t_full, 1),
            "probe_s": round(t_probe, 1),
            "memory": memd,
            "cost_scan_undercounted": full_cost,
            "cost": exact,
            "probes": probe_cost,
        }
    except Exception as e:  # noqa: BLE001 — dry-run failures are findings
        rec = {"cell": cell, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-4000:]}
    _write(out_dir, cell, rec)
    if verbose:
        _print_cell(rec)
    return rec


def _write(out_dir: str, cell: str, rec: dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def _print_cell(rec: dict) -> None:
    if rec["status"] == "ok":
        m = rec["memory"]
        c = rec["cost"]
        print(f"[ok] {rec['cell']}: compile={rec['compile_s']}s+"
              f"{rec['probe_s']}s flops={c['flops']:.3e} "
              f"bytes={c['bytes_accessed']:.3e} "
              f"coll={c['coll_total_bytes']:.3e}B "
              f"args={m['argument_bytes']} temp={m['temp_bytes']}", flush=True)
    elif rec["status"] == "skipped":
        print(f"[skip] {rec['cell']}: {rec['reason']}")
    else:
        print(f"[ERR] {rec['cell']}: {rec['error']}")


def optimized_overrides(arch: str, shape_name: str) -> tuple[dict, dict]:
    """The §Perf-confirmed configuration per (arch x shape) — see
    EXPERIMENTS.md §Perf for the iteration log that selected these."""
    shape = SHAPES[shape_name]
    cfg = configs.get(arch)
    lm_kw: dict = {}
    rules_kw: dict = {}
    if shape.kind == "decode":
        # fsdp off: params stay resident, no per-token weight gathers —
        # EXCEPT for MoE archs, where FSDP's D-dim sharding doubles as
        # data-axis compute slicing for the expert einsums (removing it
        # replicated expert compute across the data axis: 2x flops, 4x
        # bytes on deepseek — refuted, see §Perf generalization note).
        if cfg.moe is None:
            rules_kw["fsdp"] = False
        # uniform-position DUS writes only pay off when the cache can be
        # head-sharded (writes become shard-local); with a seq-sharded cache
        # GSPMD lowers them to masked full-buffer selects (§Perf it1/it4 +
        # generalization check)
        if (cfg.mla is None and cfg.num_kv_heads
                and cfg.num_kv_heads % 16 == 0):
            lm_kw["assume_uniform_decode"] = True
            rules_kw["head_sharded_cache"] = True
    else:
        lm_kw["vocab_parallel"] = True
        if cfg.mla is not None:
            rules_kw["pin_attn_heads"] = True  # helps MLA, hurts plain GQA
    return lm_kw, rules_kw


def main() -> None:
    # Must run before the first jax backend init (importing jax above is
    # fine — the device count locks at init, not import).  At CLI-entry
    # rather than module top so importing this module for its parsers
    # (tests, roofline.py) never touches the device count; when it IS too
    # late, force_host_devices raises instead of silently mutating a dead
    # env var — the bug the old inline XLA_FLAGS mutation here carried.
    force_host_devices(512)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="apply the §Perf-confirmed optimizations "
                         "(results tagged __opt)")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    archs = configs.names() if (args.all or args.arch is None) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = ["single", "multipod"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape_name in shapes:
                lm_kw: dict = {}
                rules_kw: dict = {}
                tag = ""
                if args.opt:
                    lm_kw, rules_kw = optimized_overrides(arch, shape_name)
                    tag = "opt"
                rec = run_cell(arch, shape_name, mesh_kind, args.out,
                               lm_overrides=lm_kw, rules_overrides=rules_kw,
                               tag=tag)
                failures += rec["status"] == "error"
    print(f"dry-run complete; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
