"""End-to-end training driver with fault tolerance.

Production behaviours implemented (exercised by tests/ and examples/):
  * automatic resume: on start, the latest checkpoint in --ckpt-dir is
    restored (params+opt+step) and the data stream skips ahead (stateless
    ``batch_at(step)`` — no data duplication across restarts);
  * periodic async checkpointing (previous save joined before the next);
  * straggler watchdog: per-step wall-time EWMA; steps slower than
    ``straggler_factor`` x EWMA are logged with their step index (on real
    fleets this feeds the scheduler's hot-spare logic — here it is the
    observable hook);
  * elastic rescale: restoring onto a different mesh re-places every shard
    (training/checkpoint.py restore + current Rules' shardings);
  * optional int8 error-feedback gradient compression (--compress-grads).

On CPU this trains the reduced configs (examples/train_tiny_lm.py); on a real
fleet the same driver runs the full configs under the production mesh.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax

from repro import configs
from repro.configs.reduced import reduced
from repro.models.lm import LM
from repro.training import checkpoint as ckpt
from repro.training import compression
from repro.training.data import DataConfig, SyntheticStream
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import TrainConfig, init_train_state, train_step


# frozen (RPL004): run options are read-only once constructed
@dataclasses.dataclass(frozen=True)
class RunConfig:
    arch: str
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    stop_after: Optional[int] = None  # simulate a crash at this step
    lr: float = 3e-4
    reduced: bool = True
    compress_grads: bool = False
    straggler_factor: float = 3.0
    seed: int = 0
    log_every: int = 10


def train(run: RunConfig, mesh=None, rules=None) -> dict:
    cfg = configs.get(run.arch)
    if run.reduced:
        cfg = reduced(cfg)
    lm = LM(cfg)
    tcfg = TrainConfig(adamw=AdamWConfig(lr=run.lr, total_steps=run.steps,
                                         warmup_steps=max(run.steps // 10, 1)))
    stream = SyntheticStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=run.seq_len,
        global_batch=run.global_batch, seed=run.seed))

    state = init_train_state(lm, jax.random.key(run.seed))
    err_state = (compression.init_error_state(state["params"])
                 if run.compress_grads else None)
    start_step = 0
    if run.ckpt_dir:
        latest = ckpt.latest_step(run.ckpt_dir)
        if latest is not None:
            template = jax.eval_shape(lambda: init_train_state(
                lm, jax.random.key(run.seed)))
            shardings = None
            if rules is not None:
                shardings = rules.to_shardings(rules.state_spec(template))
            state = ckpt.restore(run.ckpt_dir, latest, template, shardings)
            start_step = latest
            print(f"[train] resumed from step {latest}")

    shard = rules.act_shard() if rules is not None else (lambda x, n: x)

    def step_fn(state, batch, err):
        if err is not None:
            def xform(grads):
                g2, new_err = compression.compress_decompress(grads, err)
                xform.new_err = new_err
                return g2
            # compression must be traced inside jit; wrap functionally:
            def full(state, batch, err):
                def loss_grads(s, b):
                    return train_step(lm, tcfg, s, b, shard=shard,
                                      grad_transform=None)
                # run train_step with a transform closure capturing err
                holder = {}

                def gt(grads):
                    g2, new_err = compression.compress_decompress(grads, err)
                    holder["err"] = new_err
                    return g2

                new_state, metrics = train_step(lm, tcfg, state, batch,
                                                shard=shard, grad_transform=gt)
                return new_state, metrics, holder["err"]

            return full(state, batch, err)
        new_state, metrics = train_step(lm, tcfg, state, batch, shard=shard)
        return new_state, metrics, None

    jit_kwargs = {}
    if rules is not None:
        spec = rules.to_shardings(rules.state_spec(state))
        jit_kwargs = dict(in_shardings=(spec, None, None),
                          out_shardings=(spec, None, None))
    jstep = jax.jit(step_fn, donate_argnums=(0,), **jit_kwargs)

    ewma = None
    slow_steps = []
    losses = []
    pending_save = None
    stop_at = min(run.steps, run.stop_after or run.steps)
    for step in range(start_step, stop_at):
        batch = stream.batch_at(step, jax.process_index(),
                                jax.process_count())
        # wall-clock feeds the straggler watchdog's step timing (an
        # observability feature, not training logic) — exempt from RPL003
        # via the replint baseline
        t0 = time.time()
        state, metrics, err_state = jstep(state, batch, err_state)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        if dt > run.straggler_factor * ewma and step > start_step + 3:
            slow_steps.append((step, round(dt, 3)))
            print(f"[watchdog] straggler step {step}: {dt:.3f}s "
                  f"(ewma {ewma:.3f}s)")
        losses.append(loss)
        if run.log_every and step % run.log_every == 0:
            print(f"[train] step {step} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
        if run.ckpt_dir and (step + 1) % run.ckpt_every == 0:
            if pending_save is not None:
                pending_save.join()
            pending_save = ckpt.save(run.ckpt_dir, step + 1, state,
                                     blocking=False)
    if pending_save is not None:
        pending_save.join()
    if run.ckpt_dir:
        ckpt.save(run.ckpt_dir, stop_at, state)
    return {"losses": losses, "slow_steps": slow_steps, "state": state,
            "final_loss": losses[-1] if losses else None}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.names())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true",
                    help="full config (requires a real fleet)")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()
    out = train(RunConfig(
        arch=args.arch, steps=args.steps, seq_len=args.seq_len,
        global_batch=args.global_batch, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, lr=args.lr, reduced=not args.full,
        compress_grads=args.compress_grads))
    print(f"final loss: {out['final_loss']:.4f}; "
          f"stragglers: {out['slow_steps']}")


if __name__ == "__main__":
    main()
