"""Batched serving driver: admit a stream of requests, decode with parked KV
pages, report throughput and pool health.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-7b \
        --requests 16 --prompt-len 8 --gen-len 16
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import configs
from repro.configs.reduced import reduced
from repro.models.lm import LM
from repro.serving.engine import EngineConfig, ServeEngine
from repro.serving.pool import PoolConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-7b",
                    choices=[n for n in configs.names()])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen-len", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--pages", type=int, default=256)
    ap.add_argument("--page-tokens", type=int, default=8)
    ap.add_argument("--full", action="store_true",
                    help="full config (requires a real fleet)")
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    lm = LM(cfg, remat_policy="off")
    params = lm.init_params(jax.random.key(0))
    eng = ServeEngine(lm, params, EngineConfig(
        max_batch=args.max_batch,
        max_pages_per_req=(args.prompt_len + args.gen_len)
        // args.page_tokens + 2,
        pool=PoolConfig(num_pages=args.pages, page_tokens=args.page_tokens)))

    rng = jax.random.key(1)
    pending = list(range(args.requests))
    done = 0
    # wall-clock measures serving throughput for the printed report only —
    # exempt from RPL003 via the replint baseline
    t0 = time.time()
    toks_out = 0
    steps_left = {}
    while pending or steps_left:
        # admit while there is room
        while pending and (~eng.active).any():
            rid = pending.pop(0)
            rng, k = jax.random.split(rng)
            prompt = jax.random.randint(
                k, (args.prompt_len,), 0, cfg.vocab_size).tolist()
            if eng.admit(rid, prompt):
                steps_left[rid] = args.gen_len
        eng.step()
        toks_out += int(eng.active.sum())
        for rid in list(steps_left):
            steps_left[rid] -= 1
            if steps_left[rid] <= 0:
                eng.finish(rid)
                del steps_left[rid]
                done += 1
    dt = time.time() - t0
    print(f"served {done} requests, {toks_out} tokens in {dt:.1f}s "
          f"({toks_out / dt:.1f} tok/s on CPU reference engine)")
    print("pool stats:", eng.stats())


if __name__ == "__main__":
    main()
