"""Launch layer: meshes, dry-run, end-to-end train/serve drivers."""
