"""Architecture registry: every assigned config selectable via --arch <id>."""
from __future__ import annotations

from repro.configs.base import ModelConfig

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    from repro.configs import (  # noqa: F401
        gemma_7b, minitron_8b, qwen3_32b, qwen2_5_3b, mixtral_8x7b,
        deepseek_v2_236b, qwen2_vl_72b, recurrentgemma_9b,
        seamless_m4t_large_v2, mamba2_1_3b,
    )
