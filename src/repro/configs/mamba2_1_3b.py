"""mamba2-1.3b [ssm]: 48L d_model=2048 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]"""
from repro.configs import register
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = register(ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=64,        # d_inner / head_dim = 4096/64
    num_kv_heads=0,      # attention-free
    head_dim=64,
    d_ff=0,              # no separate FFN: the Mamba block is the mixer
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=128,
                  conv_width=4, n_groups=1),
    source="[arXiv:2405.21060; unverified]",
))
