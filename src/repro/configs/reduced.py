"""Reduced (CPU-smoke) variants of every assigned architecture.

Same family, same code paths (GQA ratios, MoE routing, MLA ranks, hybrid
pattern, SSD chunks) — tiny dimensions.  The FULL configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation); these run real forward /
train / decode steps on CPU in the smoke tests.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (HybridConfig, MLAConfig, ModelConfig,
                                MoEConfig, SSMConfig)


def reduced(cfg: ModelConfig) -> ModelConfig:
    kw: dict = dict(
        name=cfg.name + "-reduced",
        num_layers=min(cfg.num_layers, 4),
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads,
                                4 * cfg.num_kv_heads // max(cfg.num_heads, 1))
                         ) if cfg.num_kv_heads else 0,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
    )
    if cfg.family == "hybrid":
        kw["num_layers"] = 5  # exercises pattern remainder (3 + 2)
        kw["hybrid"] = HybridConfig(pattern=cfg.hybrid.pattern, d_rnn=64,
                                    conv_width=cfg.hybrid.conv_width,
                                    local_window=16)
    if cfg.ssm is not None:
        kw["num_heads"] = 8   # d_inner/head_dim = 128/16
        kw["ssm"] = SSMConfig(d_state=16, head_dim=16, expand=2, chunk=8,
                              conv_width=cfg.ssm.conv_width, n_groups=1)
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(
            num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64,
            shared_experts=cfg.moe.shared_experts,
            first_dense_layers=cfg.moe.first_dense_layers,
            group_tokens=32,
        )
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                              rope_head_dim=8, nope_head_dim=16, v_head_dim=16)
    if cfg.enc_layers:
        kw["enc_layers"] = 2
    if cfg.window is not None:
        kw["window"] = 16
    if cfg.mrope_sections is not None:
        kw["mrope_sections"] = (2, 3, 3)  # sums to head_dim/2 = 8
    return dataclasses.replace(cfg, **kw)
