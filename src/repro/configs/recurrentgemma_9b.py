"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attn, pattern 2 recurrent : 1 attention
(Griffin).  [arXiv:2402.19427; unverified]"""
from repro.configs import register
from repro.configs.base import HybridConfig, ModelConfig

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,      # MQA in the local-attention blocks
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    act="gelu",          # GeGLU (gemma family)
    rope_theta=10000.0,
    tie_embeddings=True,
    embed_scale=True,
    hybrid=HybridConfig(pattern=("rec", "rec", "attn"), d_rnn=4096,
                        conv_width=4, local_window=2048),
    source="[arXiv:2402.19427; unverified]",
))
