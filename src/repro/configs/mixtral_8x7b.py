"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8e top-2, SWA.  [arXiv:2401.04088; hf]"""
from repro.configs import register
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = register(ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    act="silu",
    rope_theta=1_000_000.0,
    window=4096,  # sliding-window attention -> bounded decode state
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336),
    source="[arXiv:2401.04088; hf]",
))
