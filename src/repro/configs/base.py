"""Model configuration schema for the assigned architecture pool.

Every architecture in src/repro/configs/<id>.py instantiates ``ModelConfig``
with the exact published numbers; ``reduced()`` derives the CPU-smoke-test
variant (same family and code paths, tiny dimensions).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    shared_experts: int = 0          # deepseek-v2: 2 shared experts
    first_dense_layers: int = 0      # deepseek-v2: layer 0 uses dense FFN
    capacity_factor: float = 1.25
    group_tokens: int = 1024         # dispatch group size (tokens)
    router_scale: float = 1.0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 128
    conv_width: int = 4
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Griffin-style block pattern: ``pattern`` repeats; e.g. ("rec","rec","attn")."""
    pattern: tuple[str, ...] = ("rec", "rec", "attn")
    d_rnn: Optional[int] = None      # RG-LRU width (defaults to d_model)
    conv_width: int = 4
    local_window: int = 2048


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | hybrid | audio | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    act: str = "silu"                # silu (SwiGLU) | gelu (GeGLU — gemma)
    qk_norm: bool = False            # qwen3
    qkv_bias: bool = False           # qwen2.5 / qwen2-vl
    rope_theta: float = 10000.0
    window: Optional[int] = None     # sliding-window attention (mixtral)
    logits_softcap: Optional[float] = None
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma family: x *= sqrt(d_model)
    norm_eps: float = 1e-6

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None

    # enc-dec (seamless-m4t): encoder layer count; num_layers = decoder layers
    enc_layers: int = 0
    # vlm (qwen2-vl): M-RoPE section split of head_dim/2 rotary channels
    mrope_sections: Optional[tuple[int, int, int]] = None

    # citation tag: [source; verification-tier]
    source: str = ""

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can serve 500k-token contexts (bounded attention state)."""
        return (self.family in ("ssm", "hybrid")
                or self.window is not None)

    def vocab_padded(self, divisor: int = 256) -> int:
        """Vocab padded for clean TP sharding (Megatron practice)."""
        return math.ceil(self.vocab_size / divisor) * divisor

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included once)."""
        d, nl = self.d_model, self.num_layers
        emb = self.vocab_padded() * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            per = (d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)
                   + d_in * d + d_in)  # in_proj + out_proj + norm-ish
            return emb + nl * per
        attn = d * self.num_heads * self.head_dim * 2 \
            + d * self.num_kv_heads * self.head_dim * 2
        if self.mla is not None:
            m = self.mla
            attn = (d * m.q_lora_rank
                    + m.q_lora_rank * self.num_heads * (m.nope_head_dim + m.rope_head_dim)
                    + d * (m.kv_lora_rank + m.rope_head_dim)
                    + m.kv_lora_rank * self.num_heads * (m.nope_head_dim + m.v_head_dim)
                    + self.num_heads * m.v_head_dim * d)
        if self.moe is not None:
            mo = self.moe
            ffn_moe = 3 * d * mo.d_ff_expert * mo.num_experts \
                + 3 * d * mo.d_ff_expert * mo.shared_experts + d * mo.num_experts
            ffn_dense = 3 * d * self.d_ff
            n_moe = nl - mo.first_dense_layers
            ffn_total = n_moe * ffn_moe + mo.first_dense_layers * ffn_dense
        else:
            ffn_total = nl * 3 * d * self.d_ff
        enc = self.enc_layers * (attn * 2 + 3 * d * self.d_ff)  # enc + cross approx
        return emb + nl * attn + ffn_total + enc

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE top-k instead of all experts)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        d, nl = self.d_model, self.num_layers
        full = self.param_count()
        all_experts = (nl - mo.first_dense_layers) * 3 * d * mo.d_ff_expert * mo.num_experts
        active = (nl - mo.first_dense_layers) * 3 * d * mo.d_ff_expert * mo.top_k
        return full - all_experts + active
