"""Run-shape presets for the scenario matrix (repro.scenarios).

A ``RunShape`` fixes the trace geometry every scenario in a sweep shares —
packet count, chunk (per-step packets), in-flight window and payload-buffer
capacity.  Two presets exist:

  * ``FULL`` — the paper-scale evaluation grid (nightly CI, local runs);
  * ``TINY`` — the CI smoke geometry, small enough that every benchmark
    finishes in seconds on a CPU runner while still exercising multi-chunk
    timelines (8 steps) and a non-degenerate recirculation lane.

Scenario factories (repro.scenarios.matrix) take ``tiny: bool`` and pick
one of these, so "what does --tiny mean" is defined in exactly one place
instead of per-bench argument mangling.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class RunShape:
    """Trace geometry shared by the scenarios of one sweep."""

    packets: int   # offered packets per scenario point
    chunk: int     # packets per engine step (must divide packets)
    window: int    # in-flight chunks between Split and Merge
    pmax: int      # PacketBatch payload-buffer capacity (bytes)

    def __post_init__(self):
        if self.packets % self.chunk:
            raise ValueError(
                f"packets ({self.packets}) must be a multiple of "
                f"chunk ({self.chunk})")

    @property
    def steps(self) -> int:
        return self.packets // self.chunk


FULL = RunShape(packets=16384, chunk=256, window=2, pmax=2048)
TINY = RunShape(packets=512, chunk=64, window=2, pmax=512)


def shape(tiny: bool) -> RunShape:
    return TINY if tiny else FULL
