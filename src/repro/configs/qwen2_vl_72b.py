"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution.  Backbone only; the vision
frontend is a stub (input_specs provides precomputed patch embeddings).
[arXiv:2409.12191; hf]"""
from repro.configs import register
from repro.configs.base import ModelConfig

CONFIG = register(ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    act="silu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),  # (t, h, w) rotary channel split
    source="[arXiv:2409.12191; hf]",
))
