"""Assigned input shapes and per-(arch x shape) applicability.

LM transformer shapes are seq_len x global_batch.  ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token with a KV cache of seq_len), NOT
``train_step``.  ``long_500k`` needs sub-quadratic attention: it runs for
SSM / hybrid / sliding-window archs and is skipped (with the reason recorded)
for pure full-attention archs — see DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for one (arch x shape) cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full quadratic attention: 500k-token decode state "
                       "is unbounded; skipped per the assignment brief "
                       "(runs only for SSM/hybrid/sliding-window archs)")
    return True, ""


def cells(cfg: ModelConfig):
    """All shape cells for one arch with applicability annotations."""
    out = []
    for s in SHAPES.values():
        ok, why = applicable(cfg, s)
        out.append((s, ok, why))
    return out
