"""minitron-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000 — pruned nemotron.  [arXiv:2407.14679; hf]"""
from repro.configs import register
from repro.configs.base import ModelConfig

CONFIG = register(ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    act="silu",
    rope_theta=10000.0,
    source="[arXiv:2407.14679; hf]",
))
