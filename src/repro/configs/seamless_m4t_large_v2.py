"""seamless-m4t-large-v2 [audio]: 24L d_model=1024 16H (GQA kv=16) d_ff=8192
vocab=256206 — enc-dec, multimodal.  Backbone only; the speech frontend is a
stub (input_specs provides precomputed frame embeddings).
[arXiv:2308.11596; hf]"""
from repro.configs import register
from repro.configs.base import ModelConfig

CONFIG = register(ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,       # text decoder layers
    enc_layers=24,       # speech encoder layers (frontend stubbed)
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    act="silu",
    rope_theta=10000.0,
    source="[arXiv:2308.11596; hf]",
))
