"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff=1536 (routed expert)
vocab=102400, MoE 160e top-6 — MLA kv_lora=512, 2 shared + 160 routed.
First layer dense FFN (d_ff=12288).  [arXiv:2405.04434; hf]"""
from repro.configs import register
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,   # nominal; MLA replaces classic KV heads
    head_dim=128,
    d_ff=12288,         # layer-0 dense FFN width (DSv2)
    vocab_size=102400,
    act="silu",
    rope_theta=10000.0,
    moe=MoEConfig(
        num_experts=160, top_k=6, d_ff_expert=1536,
        shared_experts=2, first_dense_layers=1,
    ),
    mla=MLAConfig(
        kv_lora_rank=512, q_lora_rank=1536,
        rope_head_dim=64, nope_head_dim=128, v_head_dim=128,
    ),
    source="[arXiv:2405.04434; hf]",
))
