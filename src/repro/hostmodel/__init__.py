"""NF-server host model: PCIe link + NIC/DMA + per-server cycle budget.

Closes the loop on the abstract's end-host claim ("reduces PCIe bus load
by 2-58%"): the switch-side engine produces per-link telemetry
(``switchsim.telemetry``), this package turns it into PCIe bus load, DMA
byte accounting and server-bound throughput (DESIGN.md §7).
"""
from repro.hostmodel.nic import (DmaLoad, baseline_dma, parked_dma,
                                 pcie_reduction)
from repro.hostmodel.pcie import PcieLink
from repro.hostmodel.server import (HostModel, ServerBound,
                                    cycles_per_packet, per_server_capacity,
                                    server_bound_pps, server_report,
                                    servers_per_pipe)

__all__ = [
    "DmaLoad", "baseline_dma", "parked_dma", "pcie_reduction",
    "PcieLink", "HostModel", "ServerBound", "cycles_per_packet",
    "per_server_capacity", "server_bound_pps", "server_report",
    "servers_per_pipe",
]
