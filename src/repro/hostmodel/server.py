"""Per-server cycle budget: NF compute + data movement bound server pps
(DESIGN.md §7).

NFSlicer and "Benchmarking NFV Software Dataplanes" (PAPERS.md) both show
that for shallow NFs the *per-packet host cost* — DMA, descriptor
handling, cache fills — bounds throughput at least as often as NF cycles
do.  ``HostModel`` therefore charges each packet:

    cycles = slowest-NF cycles (OpenNetVM pins one NF per core, §6.1)
           + fixed DPDK/framework overhead
           + cycles_per_byte x bytes touched (RX + TX DMA'd bytes)

and bounds server-side pps by the minimum of four capacities: CPU,
PCIe RX byte rate, PCIe TX byte rate (full duplex, each direction owns
``PcieLink.effective_gbps``), and the NIC's DMA transaction rate.

Parking helps through the ``cycles_per_byte`` and PCIe terms: header-only
packets touch ~103 B instead of e.g. 512 B, so the same core budget
yields more pps — the end-host half of the paper's goodput story.
"""
from __future__ import annotations

import dataclasses
import math

from repro.hostmodel.nic import baseline_dma, parked_dma, pcie_reduction
from repro.hostmodel.pcie import PcieLink
from repro.switchsim.telemetry import LinkTelemetry


@dataclasses.dataclass(frozen=True)
class HostModel:
    """One NF server behind one switch pipe (§6.3.2: pipe == server)."""

    link: PcieLink = PcieLink()
    cpu_ghz: float = 2.3           # Xeon E7-4870 v2 (§6.1)
    cores_per_nf: int = 1          # OpenNetVM pins each NF to one core
    overhead_cycles: float = 60.0  # DPDK rx/tx + framework per packet
    cycles_per_byte: float = 0.2   # data-movement cost (DMA/LLC, NFSlicer)
    dma_txn_mpps: float = 31.5     # NIC DMA transaction cap (§6.2.2)

    def __post_init__(self):
        if self.cpu_ghz <= 0 or self.cores_per_nf < 1:
            raise ValueError("cpu_ghz must be > 0 and cores_per_nf >= 1")
        if min(self.overhead_cycles, self.cycles_per_byte,
               self.dma_txn_mpps) < 0:
            raise ValueError("per-packet costs must be non-negative")


def _slowest_nf(nf_cycles) -> float:
    if isinstance(nf_cycles, (int, float)):
        return float(nf_cycles)
    return max(float(c) for c in nf_cycles)


def cycles_per_packet(hm: HostModel, nf_cycles,
                      touched_bytes: float) -> float:
    """Per-packet cycle budget: slowest NF + framework + data movement."""
    return (_slowest_nf(nf_cycles) + hm.overhead_cycles
            + hm.cycles_per_byte * max(touched_bytes, 0.0))


@dataclasses.dataclass(frozen=True)
class ServerBound:
    """Server-side pps bound and the resource that sets it."""

    pps: float
    bottleneck: str              # 'cpu' | 'pcie_rx' | 'pcie_tx' | 'dma_txn'
    cycles_per_pkt: float
    caps: dict = dataclasses.field(default_factory=dict)


def server_bound_pps(hm: HostModel, nf_cycles,
                     rx_bytes_per_pkt: float,
                     tx_bytes_per_pkt: float) -> ServerBound:
    """Max packets/s one server sustains at the given per-packet DMA sizes.

    ``rx_bytes_per_pkt``/``tx_bytes_per_pkt`` are mean *data* bytes per
    packet per direction (e.g. ``DmaLoad.rx_bytes / rx_pkts``); the PCIe
    terms add TLP/descriptor overheads via ``PcieLink.mean_bus_bytes``.
    """
    cyc = cycles_per_packet(hm, nf_cycles,
                            rx_bytes_per_pkt + tx_bytes_per_pkt)
    byte_rate = hm.link.effective_gbps * 1e9 / 8  # bytes/s per direction
    caps = {"cpu": hm.cores_per_nf * hm.cpu_ghz * 1e9 / cyc,
            "dma_txn": hm.dma_txn_mpps * 1e6}
    rx_bus = hm.link.mean_bus_bytes(rx_bytes_per_pkt)
    tx_bus = hm.link.mean_bus_bytes(tx_bytes_per_pkt)
    if rx_bus > 0:
        caps["pcie_rx"] = byte_rate / rx_bus
    if tx_bus > 0:
        caps["pcie_tx"] = byte_rate / tx_bus
    bottleneck = min(caps, key=caps.get)
    return ServerBound(pps=caps[bottleneck], bottleneck=bottleneck,
                       cycles_per_pkt=cyc, caps=caps)


def server_report(hm: HostModel, tel: LinkTelemetry, nf_cycles) -> dict:
    """Full host-side accounting for one server's measured telemetry.

    Combines the NIC/DMA byte accounting (parked vs drop-aware baseline)
    with the cycle-budget pps bounds of both deployments.  ``nf_cycles``
    is ``Chain.cycle_costs()`` (or any scalar/sequence of per-NF costs).
    """
    parked = parked_dma(hm.link, tel)
    base = baseline_dma(hm.link, tel)

    def mean(nbytes, pkts):
        return nbytes / pkts if pkts else 0.0

    bound_park = server_bound_pps(
        hm, nf_cycles,
        mean(parked.rx_bytes, parked.rx_pkts),
        mean(parked.tx_bytes, parked.tx_pkts))
    bound_base = server_bound_pps(
        hm, nf_cycles,
        mean(base.rx_bytes, base.rx_pkts),
        mean(base.tx_bytes, base.tx_pkts))
    return dict(
        pcie_reduction=pcie_reduction(hm.link, tel),
        parked_bus_bytes=parked.bus_bytes,
        baseline_bus_bytes=base.bus_bytes,
        parked=parked.as_dict(),
        baseline=base.as_dict(),
        server_pps_parked=bound_park.pps,
        server_pps_baseline=bound_base.pps,
        server_pps_gain=(bound_park.pps / bound_base.pps - 1.0
                         if bound_base.pps else 0.0),
        bottleneck_parked=bound_park.bottleneck,
        bottleneck_baseline=bound_base.bottleneck,
    )


# -------------------------------------------------------------------------
# Multi-server table slicing (§6.2.3 / §6.3.2)
# -------------------------------------------------------------------------

PIPES_PER_CHIP = 4  # Tofino-generation pipe count (resources.py, Table 1)


def servers_per_pipe(n_servers: int) -> int:
    """How many NF servers share one pipe's MAU when ``n_servers`` hang
    off one chip: servers fill the chip's pipes round-robin (§6.3.2 —
    8 servers on 4 pipes means 2 per pipe, Table 1's second row)."""
    if n_servers < 1:
        raise ValueError(f"n_servers must be >= 1, got {n_servers}")
    return math.ceil(n_servers / PIPES_PER_CHIP)


def per_server_capacity(frac: float, cfg, n_servers: int) -> int:
    """Lookup-table slots each of ``n_servers`` gets from ``frac`` of a
    pipe's SRAM — the §6.2.3 static slicing, delegated to the placement
    model (``resources._placement`` via ``capacity_for_memory_fraction``)
    so block rounding and per-slice replication match Table 1 exactly."""
    from repro.switchsim import resources
    return resources.capacity_for_memory_fraction(
        frac, cfg, nf_servers=servers_per_pipe(n_servers))
