"""NIC/DMA stage: what the NF server's NIC actually moves over PCIe
(DESIGN.md §7).

The input is the switch-side per-link telemetry
(``switchsim.telemetry.LinkTelemetry``, per pipe = per server under
§6.3.2 steering); the output is exact DMA byte/packet accounting for both
bus directions:

  * **RX** (switch -> server): every packet the switch forwards is DMA'd
    into host memory — *header-only* (42 B + 7 B PP header + un-parked
    tail) for parked packets, the *full packet* (+7 B) for ENB=0 traffic.
    That is exactly ``telemetry.to_server_*``: the post-Split wire bytes.
  * **TX** (server -> switch): what the NF chain sends back
    (``telemetry.from_server_*`` — chain survivors, still header-only
    when parked).

The no-parking **baseline** for the same offered traffic DMAs the full
packet both ways: RX = every offered packet whole (``wire_*``), TX = the
chain survivors at full size (``merged_*`` — the same drop-aware
convention as ``engine.goodput_gain``; a baseline deployment drops the
same packets server-side and never returns them).

``pcie_reduction`` is the headline: 1 - parked/baseline bus bytes,
TLP + descriptor overheads included.  Because the per-packet overheads do
NOT shrink (the same number of packets crosses the bus), the reduction is
strictly below the raw link-byte saving — which is what keeps it inside
the paper's 2-58% band instead of the ~60% byte saving at 256 B.
"""
from __future__ import annotations

import dataclasses

from repro.hostmodel.pcie import PcieLink
from repro.switchsim.telemetry import LinkTelemetry


@dataclasses.dataclass(frozen=True)
class DmaLoad:
    """Exact DMA accounting for one server's PCIe bus, both directions.

    ``*_bytes`` are packet data bytes DMA'd; ``*_bus_bytes`` add the
    per-TLP and per-descriptor overheads of ``PcieLink``.
    """

    rx_pkts: int
    rx_bytes: int
    rx_bus_bytes: int
    tx_pkts: int
    tx_bytes: int
    tx_bus_bytes: int

    @property
    def data_bytes(self) -> int:
        return self.rx_bytes + self.tx_bytes

    @property
    def bus_bytes(self) -> int:
        """Total bus bytes, both directions summed — the paper's 'PCIe
        bus load' unit (Fig. 9 reports utilization of the whole bus)."""
        return self.rx_bus_bytes + self.tx_bus_bytes

    def as_dict(self) -> dict[str, int]:
        return dataclasses.asdict(self)


def _load(link: PcieLink, rx_pkts: int, rx_bytes: int,
          tx_pkts: int, tx_bytes: int) -> DmaLoad:
    return DmaLoad(
        rx_pkts=rx_pkts, rx_bytes=rx_bytes,
        rx_bus_bytes=link.bus_bytes(rx_pkts, rx_bytes),
        tx_pkts=tx_pkts, tx_bytes=tx_bytes,
        tx_bus_bytes=link.bus_bytes(tx_pkts, tx_bytes),
    )


def parked_dma(link: PcieLink, tel: LinkTelemetry) -> DmaLoad:
    """DMA load with PayloadPark: header-only for parked packets, full
    packet for ENB=0 — the telemetry's server-link directions verbatim."""
    return _load(link, tel.to_server_pkts, tel.to_server_bytes,
                 tel.from_server_pkts, tel.from_server_bytes)


def baseline_dma(link: PcieLink, tel: LinkTelemetry) -> DmaLoad:
    """DMA load of a no-parking deployment of the same chain on the same
    offered traffic: full packets in, full-size survivors out."""
    return _load(link, tel.wire_pkts, tel.wire_bytes,
                 tel.merged_pkts, tel.merged_bytes)


def pcie_reduction(link: PcieLink, tel: LinkTelemetry) -> float:
    """Fractional PCIe bus-load reduction vs the no-parking baseline
    (the abstract's 2-58% claim; positive = PayloadPark relieves the bus)."""
    base = baseline_dma(link, tel).bus_bytes
    if base == 0:
        return 0.0
    return 1.0 - parked_dma(link, tel).bus_bytes / base
