"""PCIe link model: generation/width -> effective GB/s, with per-packet
TLP and DMA-descriptor overheads (DESIGN.md §7).

The paper measures PCIe relief indirectly ("PayloadPark reduces PCIe bus
load by 2-58%", abstract; §6.2.2 quotes NIC limits) but never models the
bus.  This module does, following pcie-bench (Neugebauer et al.,
SIGCOMM'18 — the paper's own reference for NIC/DMA limits):

  * **Raw rate** = per-lane transfer rate x lane count
    (Gen3 8 GT/s, Gen4 16 GT/s, ...).
  * **Encoding** takes its cut first: 8b/10b for Gen1/2 (80%),
    128b/130b from Gen3 on (~98.5%).  Gen3 x8 lands at ~63 Gbps — the
    *byte-rate ceiling* per direction (PCIe is full duplex).
  * **TLP overhead**: DMA engines move data in Transaction Layer Packets
    of at most ``max_payload`` bytes (MPS, typically 256 B); every TLP
    pays ~24 B of framing + header + LCRC.  A 1492 B packet takes 6 TLPs
    (144 B overhead); a 103 B PayloadPark header packet takes 1.
  * **Descriptor overhead**: each packet additionally costs a DMA
    descriptor fetch (read request + completion carrying the descriptor)
    and a completion/writeback — modelled as two ``desc_bytes`` transfers
    with their own TLP headers.

This is why small packets hurt: at 103 B the bus moves ~2x the packet's
bytes, which reproduces the paper's §6.2.2 observation that "a modern NIC
with DPDK driver cannot operate at 40 Gbps for packets smaller than ~170
bytes" without any fitted constant.
"""
from __future__ import annotations

import dataclasses
import math

# Per-lane transfer rate (GT/s) and encoding efficiency per generation.
_GEN_GTPS = {1: 2.5, 2: 5.0, 3: 8.0, 4: 16.0, 5: 32.0}
_GEN_ENCODING = {1: 0.8, 2: 0.8, 3: 128 / 130, 4: 128 / 130, 5: 128 / 130}
_VALID_LANES = (1, 2, 4, 8, 16)


@dataclasses.dataclass(frozen=True)
class PcieLink:
    """One PCIe endpoint link (the NF server's NIC slot).

    Defaults model the paper's testbed class: Gen3 x8 (~63 Gbps effective
    byte rate per direction), 256 B Max_Payload_Size, 24 B per-TLP
    overhead (framing 2+2 B, 3-DW header with 64-bit addressing 12-16 B,
    LCRC 4 B), 16 B DMA descriptors.
    """

    gen: int = 3
    lanes: int = 8
    max_payload: int = 256   # TLP Max_Payload_Size (bytes)
    tlp_overhead: int = 24   # framing + header + LCRC per TLP (bytes)
    desc_bytes: int = 16     # one DMA descriptor (bytes)

    def __post_init__(self):
        if self.gen not in _GEN_GTPS:
            raise ValueError(
                f"gen must be one of {sorted(_GEN_GTPS)}, got {self.gen}")
        if self.lanes not in _VALID_LANES:
            raise ValueError(
                f"lanes must be one of {_VALID_LANES}, got {self.lanes}")
        if self.max_payload < 64:
            raise ValueError(
                f"max_payload must be >= 64, got {self.max_payload}")
        if self.tlp_overhead < 0 or self.desc_bytes < 0:
            raise ValueError("overheads must be non-negative")

    @property
    def raw_gbps(self) -> float:
        """Signalling rate x lanes, before encoding."""
        return _GEN_GTPS[self.gen] * self.lanes

    @property
    def effective_gbps(self) -> float:
        """Byte-rate ceiling per direction, after line encoding."""
        return self.raw_gbps * _GEN_ENCODING[self.gen]

    def data_tlps(self, nbytes: int) -> int:
        """TLPs needed to move ``nbytes`` of packet data (0 for none)."""
        if nbytes <= 0:
            return 0
        return math.ceil(nbytes / self.max_payload)

    def pkt_overhead_bytes(self, nbytes: int) -> int:
        """Bus overhead one ``nbytes`` packet pays beyond its own bytes:
        TLP headers for the data transfer plus descriptor fetch +
        completion writeback (each a ``desc_bytes`` transfer with its own
        TLP header)."""
        if nbytes <= 0:
            return 0
        return (self.data_tlps(nbytes) * self.tlp_overhead
                + 2 * (self.desc_bytes + self.tlp_overhead))

    def dma_bus_bytes(self, nbytes: int) -> int:
        """Total bus bytes one packet of ``nbytes`` costs in its direction."""
        if nbytes <= 0:
            return 0
        return nbytes + self.pkt_overhead_bytes(nbytes)

    def bus_bytes(self, pkts: int, data_bytes: int) -> int:
        """Aggregate bus bytes for ``pkts`` packets totalling ``data_bytes``.

        Per-packet overheads are charged at the *mean* packet size
        (``ceil(mean / max_payload)`` TLPs each) — exact for fixed-size
        workloads, a recorded approximation for mixed ones (DESIGN.md §7
        deviations): the switch-side telemetry carries totals, not the
        server NIC's TLP segmentation.
        """
        if pkts <= 0 or data_bytes <= 0:
            return 0
        mean = data_bytes / pkts
        return data_bytes + pkts * self.pkt_overhead_bytes(math.ceil(mean))

    def mean_bus_bytes(self, mean_pkt_bytes: float) -> float:
        """Bus bytes per packet at a (possibly fractional) mean size."""
        if mean_pkt_bytes <= 0:
            return 0.0
        return mean_pkt_bytes + self.pkt_overhead_bytes(
            math.ceil(mean_pkt_bytes))

    def data_gbps_at(self, pkt_bytes: int) -> float:
        """Packet-data throughput ceiling at a fixed packet size — the
        pcie-bench 'effective bandwidth' curve."""
        bus = self.dma_bus_bytes(pkt_bytes)
        if bus == 0:
            return 0.0
        return self.effective_gbps * pkt_bytes / bus
