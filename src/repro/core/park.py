"""PayloadPark lookup table: Split / Merge / Evict / Explicit-Drop / Recirculate.

Faithful implementation of the paper's Algorithms 1 and 2 on a JAX state
machine.  P4 guarantees *atomic, per-packet sequential* register semantics
("Thanks to the atomic nature of action execution in P4, subsequent packets in
the match-action pipeline are guaranteed to get different indexes", §5); we
reproduce that with a ``lax.scan`` over packets in arrival (FIFO) order for
the control plane (tagger + metadata table), while the bulk payload movement
(the paper's stage 3..N striping across MAT-local register arrays, Fig. 4)
and the per-packet tag CRCs route through the dataplane-backend registry
(``repro.backend``, DESIGN.md §9): a frozen ``BackendConfig`` selects the
jnp reference or the Pallas TPU kernels per primitive.

Design mapping (see DESIGN.md §2):
  P4 MAT columns holding payload blocks  ->  lane-striped rows of ``ptable``
  one stateful register access per MAT   ->  one dynamic-slice store per row
  per-port pipes                         ->  one ParkState per ingress shard
  recirculation through a second pipe    ->  ``recirculation=True`` widens the
                                             row from 160 B to 352 B (§6.2.5);
                                             one traversal still parks at most
                                             ``pass_bytes`` (160 B), and
                                             ``recirc_fn`` is the second pass
                                             that fills the upper lanes (and
                                             retries occupied-slot skips).
                                             Lane scheduling/budget live in
                                             ``switchsim.engine`` (DESIGN.md §6).

Deviations from the paper, recorded per DESIGN.md:
  * the generation clock skips 0 so that ``meta_clk == 0`` unambiguously means
    "free"; the paper's Alg. 2 compares clocks only, which is identical given
    tags never carry clk=0.
  * parked length is ``min(payload_len, park_bytes)`` recorded in a per-slot
    ``meta_len`` word.  The baseline configuration (park_bytes=160, eligibility
    payload>=160) makes this exactly the paper's fixed 160-byte parking; the
    generalization implements the paper's §7 "decoupling boundary" discussion
    and is exercised by the recirculation mode.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.backend import coerce_backend, dispatch
from repro.core import counters as C
from repro.core.header import crc16_tag, tag_valid
from repro.core.packet import OP_DROP, PacketBatch

BLOCK_BYTES = 16  # single MAT-cell width (paper Fig. 4: payload blocks P0..PL)
PARK_BYTES_BASE = 160  # paper §1: "store 160 bytes from each packet's payload"
PARK_BYTES_RECIRC = 352  # paper §6.2.5: recirculation raises 160 -> 352


@dataclasses.dataclass(frozen=True)
class ParkConfig:
    capacity: int = 4096          # M, lookup table entries
    max_exp: int = 1              # Expiry threshold (paper EXP; §6.2.4 sweeps 1/2/10)
    max_clk: int = 1 << 16        # clock rollover (2-byte register, §5)
    min_park_len: int = PARK_BYTES_BASE  # eligibility threshold (§5, §6.3.3)
    recirculation: bool = False   # §6.2.5: second pass through the pipeline
    pmax: int = 2048              # payload buffer capacity of PacketBatch
    recirc_frac: float = 0.25     # recirculation-port share of pipe capacity

    def __post_init__(self):
        if self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")
        if self.pmax < 1:
            raise ValueError(f"pmax must be >= 1, got {self.pmax}")
        if self.max_exp < 1:
            raise ValueError(f"max_exp must be >= 1, got {self.max_exp}")
        if self.max_clk < 2:
            raise ValueError(f"max_clk must be >= 2, got {self.max_clk}")
        if self.min_park_len < 1:
            raise ValueError(
                f"min_park_len must be >= 1, got {self.min_park_len}")
        if not 0.0 <= self.recirc_frac <= 1.0:
            raise ValueError(
                f"recirc_frac must be in [0, 1], got {self.recirc_frac}")

    @property
    def park_bytes(self) -> int:
        """Full lookup-table row width (accumulated across passes)."""
        return PARK_BYTES_RECIRC if self.recirculation else PARK_BYTES_BASE

    @property
    def pass_bytes(self) -> int:
        """Bytes one pipeline traversal can park (the stage budget of Fig. 4).

        The recirculation pass (``recirc_fn``) fills the remaining
        ``park_bytes - pass_bytes`` lanes; with recirculation off the two
        widths coincide and Split parks the whole row in one pass.
        """
        return min(PARK_BYTES_BASE, self.park_bytes)

    @property
    def banks(self) -> int:
        return self.park_bytes // BLOCK_BYTES


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ParkState:
    """Registers + tables of one PayloadPark pipe (paper Fig. 4)."""

    tbl_idx: jax.Array   # () int32 — TI register
    clk: jax.Array       # () int32 — CLK register
    meta_exp: jax.Array  # (M,) int32 — Expiry threshold per slot
    meta_clk: jax.Array  # (M,) int32 — generation per slot (0 = free)
    meta_len: jax.Array  # (M,) int32 — parked byte count per slot
    ptable: jax.Array    # (M, park_bytes) uint8 — lane-striped payload banks
    counters: jax.Array  # (C.NUM,) int64


def init_state(cfg: ParkConfig) -> ParkState:
    m = cfg.capacity
    return ParkState(
        tbl_idx=jnp.zeros((), jnp.int32),
        clk=jnp.zeros((), jnp.int32),
        meta_exp=jnp.zeros((m,), jnp.int32),
        meta_clk=jnp.zeros((m,), jnp.int32),
        meta_len=jnp.zeros((m,), jnp.int32),
        ptable=jnp.zeros((m, cfg.park_bytes), jnp.uint8),
        counters=C.zeros(),
    )


def occupancy(state: ParkState) -> jax.Array:
    """Number of live (parked) slots."""
    return jnp.sum(state.meta_exp > 0)


# --------------------------------------------------------------------------
# Split (paper Algorithm 1)
# --------------------------------------------------------------------------

def _split_control(cfg: ParkConfig, state: ParkState, pkts: PacketBatch):
    """Sequential tagger + metadata-table pass.  Returns per-packet decisions."""
    m = cfg.capacity

    def step(carry, x):
        ti, clk, meta_exp, meta_clk, meta_len = carry
        alive, plen = x
        eligible = alive & (plen >= cfg.min_park_len)

        # -- stage 1: packet tagger (Alg. 1 lines 4-7) ----------------------
        ti_n = jnp.where(eligible, (ti + 1) % m, ti)
        clk_n = jnp.where(eligible, clk + 1, clk)
        # generation clock skips 0 (see module docstring)
        clk_n = jnp.where(clk_n >= cfg.max_clk, 1, clk_n)

        # -- stage 2: metadata probe (Alg. 1 lines 10-25) -------------------
        exp_pre = meta_exp[ti_n]
        exp_dec = jnp.where(exp_pre >= 1, exp_pre - 1, exp_pre)  # lines 11-13
        evicted = eligible & (exp_pre >= 1) & (exp_dec == 0)
        available = exp_dec == 0                                  # line 14
        claim = eligible & available

        new_exp = jnp.where(claim, cfg.max_exp, exp_dec)
        meta_exp = jnp.where(eligible, meta_exp.at[ti_n].set(new_exp), meta_exp)
        meta_clk = jnp.where(
            claim, meta_clk.at[ti_n].set(clk_n),
            jnp.where(evicted, meta_clk.at[ti_n].set(0), meta_clk),
        )
        park_len = jnp.minimum(plen, cfg.pass_bytes)
        meta_len = jnp.where(claim, meta_len.at[ti_n].set(park_len), meta_len)

        out = dict(
            enb=claim, ti=ti_n, clk=clk_n, evicted=evicted,
            skip_occupied=eligible & ~available,
            skip_small=alive & (plen < cfg.min_park_len),
            park_len=jnp.where(claim, park_len, 0),
        )
        return (ti_n, clk_n, meta_exp, meta_clk, meta_len), out

    carry0 = (state.tbl_idx, state.clk, state.meta_exp, state.meta_clk,
              state.meta_len)
    (ti, clk, meta_exp, meta_clk, meta_len), outs = jax.lax.scan(
        step, carry0, (pkts.alive, pkts.payload_len)
    )
    return (ti, clk, meta_exp, meta_clk, meta_len), outs


def split_fn(cfg: ParkConfig, state: ParkState, pkts: PacketBatch,
             backend=None) -> tuple[ParkState, PacketBatch]:
    """Split operation: park payload prefixes, emit header-only packets.

    Returns (new_state, packets-as-sent-to-the-NF-server).  Every alive packet
    leaves with a PayloadPark header (ENB=1 if parked, else 0 — §6.1).

    ``backend`` selects the payload_store / crc16_tag implementations
    (``repro.backend``).

    This is the un-jitted body, composable inside ``lax.scan`` (the
    multi-pipe engine, DESIGN.md §3); ``split`` is the jitted entry point.
    """
    backend = coerce_backend(backend)
    (ti, clk, meta_exp, meta_clk, meta_len), d = _split_control(cfg, state, pkts)

    # -- stage 3..N: stripe payload blocks into the payload table -----------
    # Claiming zeroes the full row (incl. lanes above pass_bytes), so a later
    # recirculation pass appends into zeros.  pmax < park_bytes is legal (the
    # row is then partly unreachable); pad the slice up to the row width.
    park = pkts.payload[:, : cfg.park_bytes]
    if park.shape[1] < cfg.park_bytes:
        park = jnp.pad(park, ((0, 0), (0, cfg.park_bytes - park.shape[1])))
    lane = jnp.arange(cfg.park_bytes)[None, :]
    park = jnp.where(lane < d["park_len"][:, None], park, 0)
    ptable = dispatch("payload_store", backend)(
        state.ptable, park, d["ti"], d["enb"])

    counters = state.counters
    counters = C.bump(counters, "splits", jnp.sum(d["enb"]))
    counters = C.bump(counters, "evictions", jnp.sum(d["evicted"]))
    counters = C.bump(counters, "skip_occupied", jnp.sum(d["skip_occupied"]))
    counters = C.bump(counters, "skip_small_payload", jnp.sum(d["skip_small"]))

    new_state = ParkState(ti, clk, meta_exp, meta_clk, meta_len, ptable, counters)

    # -- packet transformation: drop the parked prefix, add the PP header ---
    shift = d["park_len"]
    idx = jnp.arange(cfg.pmax)[None, :] + shift[:, None]
    remainder = jnp.take_along_axis(
        pkts.payload, jnp.clip(idx, 0, cfg.pmax - 1), axis=1
    )
    new_len = pkts.payload_len - shift
    keep = jnp.arange(cfg.pmax)[None, :] < new_len[:, None]
    remainder = jnp.where(keep, remainder, 0)

    enb32 = d["enb"].astype(jnp.int32)
    out = pkts.replace(
        payload=jnp.where(pkts.alive[:, None], remainder, pkts.payload),
        payload_len=jnp.where(pkts.alive, new_len, pkts.payload_len),
        pp_valid=pkts.alive,
        pp_enb=jnp.where(pkts.alive, enb32, 0),
        pp_op=jnp.zeros_like(pkts.pp_op),
        pp_ti=jnp.where(d["enb"], d["ti"], 0),
        pp_clk=jnp.where(d["enb"], d["clk"], 0),
        pp_crc=jnp.where(d["enb"],
                         crc16_tag(d["ti"], d["clk"], backend=backend), 0),
    )
    return new_state, out


split = partial(jax.jit, static_argnames=("cfg", "backend"))(split_fn)


# --------------------------------------------------------------------------
# Recirculation pass (paper §6.2.5)
# --------------------------------------------------------------------------

def _select_rows(mask, a, b):
    """Per-row select between two identically-shaped PacketBatches."""
    return jax.tree.map(
        lambda x, y: jnp.where(
            mask.reshape(mask.shape + (1,) * (x.ndim - mask.ndim)), x, y),
        a, b)


def recirc_fn(cfg: ParkConfig, state: ParkState, pkts: PacketBatch,
              backend=None) -> tuple[ParkState, PacketBatch]:
    """One recirculation pass for packets re-injected through the
    recirculation port (paper §6.2.5).  Two cases, handled in order:

      * **continuation** (ENB=1 with payload remaining): append up to
        ``park_bytes - meta_len[TI]`` more payload bytes into the packet's
        existing row — the second traversal reaches the stages holding the
        upper lanes of the 352-byte row.  The tag (TI, CLK, CRC) is
        unchanged; the write is skipped if the slot was evicted in between
        (the stale tag then surfaces as a premature eviction at Merge,
        exactly as it would without recirculation).
      * **retry** (ENB=0 after an occupied-slot skip): a fresh Split
        attempt — the tagger hands out the next index, which may have been
        freed or expired since the first pass.  A retry that fails again
        counts another ``skip_occupied`` (counters are per attempt).

    Packets come out NF-bound; lane scheduling and the recirculation-port
    budget live in ``switchsim.engine`` (DESIGN.md §6).  The partial-row
    append stays on the plain-JAX path (the Pallas store kernel writes
    whole rows — a recorded deviation, DESIGN.md §9); retry Splits honour
    ``backend``.
    """
    backend = coerce_backend(backend)
    counters = C.bump(state.counters, "recirculations",
                      jnp.sum(pkts.alive & pkts.pp_valid))

    # -- continuation: append into the owned row ----------------------------
    ext = pkts.alive & pkts.pp_valid & (pkts.pp_enb == 1)
    ti = jnp.clip(pkts.pp_ti, 0, cfg.capacity - 1)
    own = ext & (state.meta_clk[ti] == pkts.pp_clk)
    cur = jnp.where(own, state.meta_len[ti], 0)
    extra = jnp.where(
        own,
        jnp.minimum(pkts.payload_len, jnp.maximum(cfg.park_bytes - cur, 0)),
        0)
    do_ext = own & (extra > 0)

    col = jnp.arange(cfg.park_bytes)[None, :]
    src = col - cur[:, None]
    ins = jnp.take_along_axis(
        pkts.payload, jnp.clip(src, 0, cfg.pmax - 1), axis=1)
    region = (src >= 0) & (src < extra[:, None])
    new_row = jnp.where(region, ins, state.ptable[ti])
    rows = jnp.where(do_ext, ti, cfg.capacity)  # OOB rows dropped
    ptable = state.ptable.at[rows].set(new_row, mode="drop")
    meta_len = state.meta_len.at[rows].set(cur + extra, mode="drop")

    idx = jnp.arange(cfg.pmax)[None, :] + extra[:, None]
    remainder = jnp.take_along_axis(
        pkts.payload, jnp.clip(idx, 0, cfg.pmax - 1), axis=1)
    new_len = pkts.payload_len - extra
    keep = jnp.arange(cfg.pmax)[None, :] < new_len[:, None]
    remainder = jnp.where(keep, remainder, 0)
    ext_out = pkts.replace(
        payload=jnp.where(do_ext[:, None], remainder, pkts.payload),
        payload_len=jnp.where(do_ext, new_len, pkts.payload_len),
    )
    mid = ParkState(state.tbl_idx, state.clk, state.meta_exp, state.meta_clk,
                    meta_len, ptable, counters)

    # -- retry: a second Split attempt for ENB=0 packets --------------------
    retry = pkts.alive & pkts.pp_valid & (pkts.pp_enb == 0)
    retry_in = ext_out.replace(alive=retry)
    new_state, retry_out = split_fn(cfg, mid, retry_in, backend=backend)
    # split_fn rewrites header fields of its whole batch; keep its result
    # only for the retry rows, the extension result for everything else.
    return new_state, _select_rows(retry, retry_out, ext_out)


recirc = partial(jax.jit, static_argnames=("cfg", "backend"))(recirc_fn)


# --------------------------------------------------------------------------
# Merge + Explicit Drop (paper Algorithm 2, §6.2.4)
# --------------------------------------------------------------------------

def _merge_control(cfg: ParkConfig, state: ParkState, pkts: PacketBatch,
                   backend=None):
    """Sequential metadata validation/free pass (Alg. 2 stages 1-2).

    The tag CRC check is pure per-packet math (independent of the table
    carry), so it runs batched through the backend dispatch BEFORE the
    sequential scan — on ``backend="pallas"`` the whole header validation
    is one kernel call instead of a per-packet bit loop.
    """
    crc_ok_all = tag_valid(pkts.pp_ti, pkts.pp_clk, pkts.pp_crc,
                           backend=backend)

    def step(carry, x):
        meta_exp, meta_clk, meta_len = carry
        alive, valid, enb, op, ti, clk, crc_ok = x
        is_pp = alive & valid & (enb == 1)
        checked = is_pp & crc_ok
        gen_ok = meta_clk[ti] == clk
        matched = checked & gen_ok                       # Alg. 2 line 11
        # free the slot (Alg. 2 line 13)
        meta_exp = jnp.where(matched, meta_exp.at[ti].set(0), meta_exp)
        meta_clk = jnp.where(matched, meta_clk.at[ti].set(0), meta_clk)
        plen = jnp.where(matched, meta_len[ti], 0)
        meta_len = jnp.where(matched, meta_len.at[ti].set(0), meta_len)
        out = dict(
            matched=matched,
            premature=checked & ~gen_ok,
            crc_fail=is_pp & ~crc_ok,
            disabled=alive & valid & (enb == 0),
            is_drop_op=matched & (op == OP_DROP),
            park_len=plen,
        )
        return (meta_exp, meta_clk, meta_len), out

    xs = (pkts.alive, pkts.pp_valid, pkts.pp_enb, pkts.pp_op,
          pkts.pp_ti, pkts.pp_clk, crc_ok_all)
    carry0 = (state.meta_exp, state.meta_clk, state.meta_len)
    (meta_exp, meta_clk, meta_len), outs = jax.lax.scan(step, carry0, xs)
    return (meta_exp, meta_clk, meta_len), outs


def merge_fn(cfg: ParkConfig, state: ParkState, pkts: PacketBatch,
             backend=None) -> tuple[ParkState, PacketBatch]:
    """Merge (and Explicit Drop) for packets returning from the NF server.

    Outcomes per packet:
      * ENB=0: PayloadPark header removed, packet forwarded (Alg. 2 stage 1).
      * ENB=1, OP=merge, tag valid: payload re-attached, slot freed.
      * ENB=1, OP=drop, tag valid: slot freed, packet consumed (§6.2.4).
      * CRC or generation mismatch: packet dropped, counted.

    ``backend`` selects the payload_fetch / crc16_tag implementations
    (``repro.backend``).

    Un-jitted body for ``lax.scan`` composition (DESIGN.md §3); ``merge`` is
    the jitted entry point.
    """
    backend = coerce_backend(backend)
    (meta_exp, meta_clk, meta_len), d = _merge_control(cfg, state, pkts,
                                                       backend=backend)

    # -- stage 3..N: gather payload blocks, then clear the rows --------------
    fetch = d["matched"] & ~d["is_drop_op"]
    parked, ptable = dispatch("payload_fetch", backend)(
        state.ptable, pkts.pp_ti, d["matched"])

    counters = state.counters
    counters = C.bump(counters, "merges", jnp.sum(fetch))
    counters = C.bump(counters, "explicit_drops", jnp.sum(d["is_drop_op"]))
    counters = C.bump(counters, "disabled_returns", jnp.sum(d["disabled"]))
    counters = C.bump(counters, "premature_evictions", jnp.sum(d["premature"]))
    counters = C.bump(counters, "crc_failures", jnp.sum(d["crc_fail"]))

    new_state = ParkState(state.tbl_idx, state.clk, meta_exp, meta_clk,
                          meta_len, ptable, counters)

    # -- packet transformation: payload := parked ++ carried remainder ------
    shift = jnp.where(fetch, d["park_len"], 0)
    col = jnp.arange(cfg.pmax)[None, :]
    rem_idx = col - shift[:, None]
    carried = jnp.take_along_axis(
        pkts.payload, jnp.clip(rem_idx, 0, cfg.pmax - 1), axis=1)
    # Clamp for pmax < park_bytes (parked length never exceeds the payload
    # that fit in pmax, so truncating the row loses nothing).
    pad = jnp.zeros((pkts.batch_size, max(cfg.pmax - cfg.park_bytes, 0)),
                    jnp.uint8)
    parked_full = jnp.concatenate([parked, pad], axis=1)[:, : cfg.pmax]
    new_payload = jnp.where(col < shift[:, None], parked_full, carried)
    new_len = pkts.payload_len + shift
    keep = col < new_len[:, None]
    new_payload = jnp.where(keep, new_payload, 0)

    forwarded = d["disabled"] | fetch
    dropped = d["premature"] | d["crc_fail"] | d["is_drop_op"]
    out = pkts.replace(
        payload=jnp.where(forwarded[:, None], new_payload, pkts.payload),
        payload_len=jnp.where(forwarded, new_len, pkts.payload_len),
        alive=pkts.alive & ~dropped,
        pp_valid=pkts.pp_valid & ~forwarded & ~dropped,
        pp_enb=jnp.where(forwarded | dropped, 0, pkts.pp_enb),
        pp_op=jnp.where(forwarded | dropped, 0, pkts.pp_op),
        pp_ti=jnp.where(forwarded | dropped, 0, pkts.pp_ti),
        pp_clk=jnp.where(forwarded | dropped, 0, pkts.pp_clk),
        pp_crc=jnp.where(forwarded | dropped, 0, pkts.pp_crc),
    )
    return new_state, out


merge = partial(jax.jit, static_argnames=("cfg", "backend"))(merge_fn)


def stats(state: ParkState) -> dict[str, Any]:
    d = C.as_dict(state.counters)
    d["occupancy"] = int(occupancy(state))
    return d
