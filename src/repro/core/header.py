"""PayloadPark header helpers: tag CRC computation and validation.

Paper Fig. 2: the 7-byte PayloadPark header carries ENB, OP, ALIGN bits and a
TAG composed of (table index, generation/clock, CRC).  "The CRC is used to
validate the PayloadPark header before merging the stored payloads with
packets returning from the NF server" (§3.2).

We use CRC-16/CCITT-FALSE (poly 0x1021, init 0xFFFF) over the 4 tag bytes
(little-endian ti, clk).  The math lives in the backend registry
(``repro.backend.ref.crc16_tag`` is the single jnp implementation,
``repro.kernels.crc16`` the Pallas one); this module is the dataplane-facing
entry point that routes through ``repro.backend.dispatch`` so Split/Merge
compute and validate tags on whichever backend the caller selected.
"""
from __future__ import annotations

import jax

# Re-exports: the constants and byte-level routine are owned by the backend
# ref module (shared with the Pallas kernel); historical importers (incl.
# tests/test_kernels.py) keep working through these names.  The function
# re-exports are exempt from RPL001 via the replint baseline: this module
# re-publishes them, it does not call them outside the dispatch.
from repro.backend.ref import (CRC_INIT, CRC_POLY,  # noqa: F401
                               crc16_bytes, tag_bytes)
from repro.backend.registry import dispatch


def crc16_tag(ti: jax.Array, clk: jax.Array, backend=None) -> jax.Array:
    """CRC over the PayloadPark tag on the selected backend."""
    return dispatch("crc16_tag", backend)(ti, clk)


def tag_valid(ti: jax.Array, clk: jax.Array, crc: jax.Array,
              backend=None) -> jax.Array:
    """Header validation performed by Merge before touching the tables."""
    return crc16_tag(ti, clk, backend=backend) == crc
