"""PayloadPark header helpers: tag CRC computation and validation.

Paper Fig. 2: the 7-byte PayloadPark header carries ENB, OP, ALIGN bits and a
TAG composed of (table index, generation/clock, CRC).  "The CRC is used to
validate the PayloadPark header before merging the stored payloads with
packets returning from the NF server" (§3.2).

We use CRC-16/CCITT-FALSE (poly 0x1021, init 0xFFFF) over the 4 tag bytes
(little-endian ti, clk).  ``crc16_tag`` is the pure-jnp oracle; the Pallas
kernel in ``repro.kernels.crc16`` must match it bit-exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

CRC_POLY = 0x1021
CRC_INIT = 0xFFFF


def crc16_bytes(data: jax.Array) -> jax.Array:
    """CRC-16/CCITT-FALSE over the trailing axis of a uint8/int32 byte array.

    ``data``: (..., N) byte values in [0, 255].  Returns (...,) int32 CRC.
    Bitwise, branch-free formulation (P4-style predication, paper §2: actions
    are short VLIW programs — the same constraint shapes this kernel).
    """
    data = data.astype(jnp.int32)
    n = data.shape[-1]
    crc = jnp.full(data.shape[:-1], CRC_INIT, jnp.int32)

    def per_byte(i, crc):
        crc = crc ^ (data[..., i] << 8)

        def per_bit(_, c):
            hi = (c >> 15) & 1
            c = (c << 1) & 0xFFFF
            return jnp.where(hi == 1, c ^ CRC_POLY, c)

        return jax.lax.fori_loop(0, 8, per_bit, crc)

    return jax.lax.fori_loop(0, n, per_byte, crc)


def tag_bytes(ti: jax.Array, clk: jax.Array) -> jax.Array:
    """Pack (ti, clk) into 4 little-endian bytes: (..., 4) int32."""
    ti = ti.astype(jnp.int32)
    clk = clk.astype(jnp.int32)
    return jnp.stack(
        [ti & 0xFF, (ti >> 8) & 0xFF, clk & 0xFF, (clk >> 8) & 0xFF], axis=-1
    )


def crc16_tag(ti: jax.Array, clk: jax.Array) -> jax.Array:
    """CRC over the PayloadPark tag (oracle; see repro.kernels.crc16)."""
    return crc16_bytes(tag_bytes(ti, clk))


def tag_valid(ti: jax.Array, clk: jax.Array, crc: jax.Array) -> jax.Array:
    """Header validation performed by Merge before touching the tables."""
    return crc16_tag(ti, clk) == crc
