"""The paper's eight PayloadPark monitoring counters (§5), plus ours.

"We maintain eight counters for monitoring PayloadPark operation": splits,
merges, explicit drops, disabled returns (ENB=0 packets back from the NF
server), total evictions, premature evictions, small-payload Split skips, and
occupied-slot Split skips.  We add a ninth (CRC failures on Merge-side header
validation, §3.2) which the paper mentions but does not enumerate, and two
for the recirculation path (§6.2.5, DESIGN.md §6): packets that took a
second pipeline pass, and recirculation candidates denied by the
recirculation-port bandwidth budget (they fall back to plain forwarding) —
plus one for the fault-injection layer (DESIGN.md §10): packets lost at an
NF server that was down when the switch forwarded them.
"""
from __future__ import annotations

import jax.numpy as jnp

NAMES = (
    "splits",              # Split operations with ENB=1 (stage 2, §5)
    "merges",              # successful Merges
    "explicit_drops",      # OP=drop packets that freed a slot (§6.2.4)
    "disabled_returns",    # packets back from NF server with ENB=0 (stage 1)
    "evictions",           # total payload evictions (expiry reached 0)
    "premature_evictions", # Merge found generation mismatch -> packet dropped
    "skip_small_payload",  # Split disabled: payload < park size (§5)
    "skip_occupied",       # Split disabled: next metadata slot occupied
    "crc_failures",        # Merge-side tag CRC validation failures
    "recirculations",      # packets that took a recirculation pass (§6.2.5)
    "recirc_budget_drops", # recirc candidates denied by the port budget
    "fault_drops",         # packets sent to a down NF server (DESIGN.md §10)
)
IDX = {n: i for i, n in enumerate(NAMES)}
NUM = len(NAMES)


def zeros():
    return jnp.zeros((NUM,), jnp.int32)


def bump(counters, name: str, amount):
    """counters.at[name] += amount (amount may be a traced scalar)."""
    return counters.at[IDX[name]].add(jnp.asarray(amount, jnp.int32))


def as_dict(counters) -> dict[str, int]:
    vals = [int(v) for v in counters]
    return dict(zip(NAMES, vals))
