"""Struct-of-arrays packet batches.

Packets are modelled the way the paper's dataplane sees them: a fixed 42-byte
Ethernet+IPv4+UDP header (paper footnote 1) whose fields the shallow NFs may
read/modify, an opaque payload byte array, and the optional 7-byte PayloadPark
header (paper Fig. 2).  A batch of B packets is a struct-of-arrays so every NF
and every PayloadPark operation is expressible as vectorized JAX ops.

The payload buffer is fixed-capacity (``PMAX``); ``payload_len`` gives the live
prefix.  ``wire_bytes`` serializes a packet batch to byte arrays so tests can
assert *wire-level* functional equivalence (paper §6.2.6 compares PCAPs).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

ETH_HDR_BYTES = 14
IPV4_HDR_BYTES = 20
UDP_HDR_BYTES = 8
HDR_BYTES = ETH_HDR_BYTES + IPV4_HDR_BYTES + UDP_HDR_BYTES  # 42, paper §1
PP_HDR_BYTES = 7  # paper Fig. 2 / §7 "fixed PayloadPark header overhead (of 7 bytes)"

# PayloadPark opcodes (paper Fig. 2: OP bit distinguishes Merge / Explicit Drop).
OP_MERGE = 0
OP_DROP = 1


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PacketBatch:
    """A batch of UDP packets (struct of arrays).

    All integer header fields are int32 (JAX-friendly); MACs are int64 (48-bit
    values fit).  ``payload`` is (B, PMAX) uint8.  ``alive`` marks packets not
    dropped by an NF or by the switch.
    """

    dst_mac: jax.Array   # (B,) int32 (48-bit MACs truncated; simulation only)
    src_mac: jax.Array   # (B,) int32
    src_ip: jax.Array    # (B,) int32 (uint32 bit pattern)
    dst_ip: jax.Array    # (B,) int32
    proto: jax.Array     # (B,) int32 (17 = UDP)
    src_port: jax.Array  # (B,) int32
    dst_port: jax.Array  # (B,) int32
    payload_len: jax.Array  # (B,) int32, live bytes in ``payload``
    payload: jax.Array   # (B, PMAX) uint8
    alive: jax.Array     # (B,) bool

    # PayloadPark header (paper Fig. 2).  Valid only when ``pp_valid``.
    pp_valid: jax.Array  # (B,) bool   — header present on the wire
    pp_enb: jax.Array    # (B,) int32  — ENB bit
    pp_op: jax.Array     # (B,) int32  — OP bit (OP_MERGE / OP_DROP)
    pp_ti: jax.Array     # (B,) int32  — TAG.table_index
    pp_clk: jax.Array    # (B,) int32  — TAG.generation (clock)
    pp_crc: jax.Array    # (B,) int32  — TAG.CRC-16 over (ti, clk)

    @property
    def batch_size(self) -> int:
        return self.src_ip.shape[0]

    @property
    def pmax(self) -> int:
        return self.payload.shape[1]

    def pkt_len(self) -> jax.Array:
        """Total on-wire length: 42B header + optional PP header + payload."""
        pp = jnp.where(self.pp_valid, PP_HDR_BYTES, 0)
        return HDR_BYTES + pp + self.payload_len

    def replace(self, **kw) -> "PacketBatch":
        return dataclasses.replace(self, **kw)


def make_udp_batch(
    key: jax.Array,
    batch: int,
    pkt_len,
    pmax: int = 2048,
    src_ip=None,
    dst_ip=None,
    src_port=None,
    dst_port=None,
) -> PacketBatch:
    """Build a batch of UDP packets with pseudorandom payload bytes.

    ``pkt_len`` may be a scalar or a (B,) array of total packet lengths
    (including the 42-byte header), mirroring the traffic generator's
    fixed-size and bimodal workloads (paper §6.1).
    """
    ks = jax.random.split(key, 6)
    pkt_len = jnp.broadcast_to(jnp.asarray(pkt_len, jnp.int32), (batch,))
    payload_len = jnp.maximum(pkt_len - HDR_BYTES, 0)
    payload = jax.random.randint(ks[0], (batch, pmax), 0, 256, dtype=jnp.int32)
    # Zero bytes beyond the live prefix so wire serialization is canonical.
    mask = jnp.arange(pmax)[None, :] < payload_len[:, None]
    payload = jnp.where(mask, payload, 0).astype(jnp.uint8)

    def _field(k, lo, hi, override):
        if override is not None:
            return jnp.broadcast_to(jnp.asarray(override, jnp.int32), (batch,))
        return jax.random.randint(k, (batch,), lo, hi, dtype=jnp.int32)

    z = jnp.zeros((batch,), jnp.int32)
    return PacketBatch(
        dst_mac=jax.random.randint(ks[1], (batch,), 0, (1 << 31) - 1, dtype=jnp.int32),
        src_mac=jax.random.randint(ks[2], (batch,), 0, (1 << 31) - 1, dtype=jnp.int32),
        src_ip=_field(ks[3], 0, (1 << 31) - 1, src_ip),
        dst_ip=_field(ks[4], 0, (1 << 31) - 1, dst_ip),
        proto=jnp.full((batch,), 17, jnp.int32),
        src_port=_field(ks[5], 1024, 65536, src_port),
        dst_port=_field(ks[5], 1024, 65536, dst_port),
        payload_len=payload_len,
        payload=payload,
        alive=jnp.ones((batch,), bool),
        pp_valid=jnp.zeros((batch,), bool),
        pp_enb=z,
        pp_op=z,
        pp_ti=z,
        pp_clk=z,
        pp_crc=z,
    )


def dead_batch(batch: int, pmax: int) -> PacketBatch:
    """All-dead batch (``alive=False`` everywhere, zero fields).

    Dead packets are no-ops for every NF and for Split/Merge (all state
    updates are predicated on ``alive``), so dead batches serve as padding:
    ring-buffer seeds and trace tails in the scanned engine (DESIGN.md §3),
    and overflow rows in pipe steering.
    """
    z = jnp.zeros((batch,), jnp.int32)
    return PacketBatch(
        dst_mac=z, src_mac=z, src_ip=z, dst_ip=z, proto=z,
        src_port=z, dst_port=z,
        payload_len=z,
        payload=jnp.zeros((batch, pmax), jnp.uint8),
        alive=jnp.zeros((batch,), bool),
        pp_valid=jnp.zeros((batch,), bool),
        pp_enb=z, pp_op=z, pp_ti=z, pp_clk=z, pp_crc=z,
    )


def gather_rows(p: PacketBatch, idx: jax.Array) -> PacketBatch:
    """Gather packets by row index; any index == batch_size yields a dead
    packet.  Used by the pipe-steering scatter (traffic.generator)."""
    padded = jax.tree.map(
        lambda a: jnp.concatenate(
            [a, jnp.zeros((1,) + a.shape[1:], a.dtype)], axis=0), p)
    return jax.tree.map(lambda a: a[idx], padded)


def to_time_major(p: PacketBatch, chunk: int) -> PacketBatch:
    """Reshape a flat (B, ...) batch into a (T, chunk, ...) trace for the
    scanned engine.  B must be a multiple of ``chunk``."""
    b = p.batch_size
    assert b % chunk == 0, (b, chunk)
    return jax.tree.map(
        lambda a: a.reshape((b // chunk, chunk) + a.shape[1:]), p)


def from_time_major(p: PacketBatch) -> PacketBatch:
    """Inverse of ``to_time_major``: (T, chunk, ...) -> (T*chunk, ...)."""
    return jax.tree.map(
        lambda a: a.reshape((a.shape[0] * a.shape[1],) + a.shape[2:]), p)


@partial(jax.jit, static_argnames=())
def wire_bytes(p: PacketBatch) -> tuple[jax.Array, jax.Array]:
    """Serialize each packet to its on-wire byte string (B, 42+7+PMAX) uint8.

    Returns (bytes, lengths).  The PayloadPark header region is included only
    when ``pp_valid``; dead packets serialize to zeros with length 0.  Used by
    the functional-equivalence tests (paper §6.2.6).
    """
    b, pmax = p.payload.shape
    width = HDR_BYTES + PP_HDR_BYTES + pmax

    def bytes_of(v, n):
        v = v.astype(jnp.int32)
        return jnp.stack(
            [((v >> (8 * i)) & 0xFF).astype(jnp.uint8) if i < 4
             else jnp.zeros_like(v, jnp.uint8) for i in range(n)], axis=-1
        )

    hdr = jnp.concatenate(
        [
            bytes_of(p.dst_mac, 6),
            bytes_of(p.src_mac, 6),
            bytes_of(jnp.full_like(p.proto, 0x0800), 2),  # ethertype
            bytes_of(p.proto, 1),
            bytes_of(p.src_ip, 4),
            bytes_of(p.dst_ip, 4),
            bytes_of(jnp.zeros_like(p.proto), 11),  # ver/ihl/tos/id/ttl/cksum pad
            bytes_of(p.src_port, 2),
            bytes_of(p.dst_port, 2),
            bytes_of(p.payload_len + UDP_HDR_BYTES, 2),
            bytes_of(jnp.zeros_like(p.proto), 2),  # udp cksum
        ],
        axis=-1,
    )
    assert hdr.shape[-1] == HDR_BYTES, hdr.shape

    pp = jnp.concatenate(
        [
            bytes_of(p.pp_enb | (p.pp_op << 1), 1),
            bytes_of(p.pp_ti, 2),
            bytes_of(p.pp_clk, 2),
            bytes_of(p.pp_crc, 2),
        ],
        axis=-1,
    )
    pp = jnp.where(p.pp_valid[:, None], pp, 0)

    out = jnp.zeros((b, width), jnp.uint8)
    out = out.at[:, :HDR_BYTES].set(hdr)
    # Payload begins right after the (optional) PP header.  Build via gather:
    # out[i, HDR + pp_len + j] = payload[i, j]
    pp_len = jnp.where(p.pp_valid, PP_HDR_BYTES, 0)
    col = jnp.arange(width)[None, :]
    src_idx = col - HDR_BYTES - pp_len[:, None]
    in_pp = (col >= HDR_BYTES) & (src_idx < 0)
    pp_idx = jnp.clip(col - HDR_BYTES, 0, PP_HDR_BYTES - 1)
    payload_region = (src_idx >= 0) & (src_idx < p.payload_len[:, None])
    gathered = jnp.take_along_axis(
        p.payload, jnp.clip(src_idx, 0, pmax - 1), axis=1
    )
    out = jnp.where(in_pp, jnp.take_along_axis(pp, pp_idx, axis=1), out)
    out = jnp.where(payload_region, gathered, out)
    out = jnp.where(p.alive[:, None], out, 0)
    length = jnp.where(p.alive, p.pkt_len(), 0)
    return out, length
