"""Traffic generation: PktGen-style UDP workloads (paper §6.1)."""
