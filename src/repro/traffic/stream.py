"""Chunked trace sources for the streaming engine (DESIGN.md §13).

The materialized path caps a run at what fits in host memory: ``make_packets``
builds the whole trace up front and ``run_engine`` scans it in one program.
A ``TraceSource`` inverts that — it is a *recipe* for a fixed-geometry
time-major trace, able to produce any step range ``[start, start+count)`` on
demand, so the streaming driver (``switchsim.stream``) can feed hours of
simulated traffic through a donated-carry segment without ever holding more
than one segment of packets live.

Two sources:

  * ``MaterializedSource`` — wraps an existing (T, chunk, ...) trace; the
    trivial one-shot source the array-based entry points coerce through
    (``as_source``), which is what makes sources THE trace API rather than
    a fourth parallel one.
  * ``SyntheticSource`` — generates chunk ``t`` as a pure function of
    ``(seed, t)`` (``jax.random.fold_in`` per step), so any segment is
    independently regenerable: constant memory, trivially replayable for
    the segment-replay oracle, and identical whether materialized up front
    or streamed.  Flow identity comes from a ``FlowPool`` — a splitmix32
    hash of the flow index, no per-flow state — sized for millions of
    concurrent flows (the materialized ``generator.flow_pool`` allocates
    and uniqueness-checks arrays, which stops scaling around 1e5).
    ``DiurnalLoad`` modulates the offered load per step (packets beyond
    the per-step offered count are dead rows), giving long runs the
    time-of-day shape steady-state tail latency is sensitive to.

Determinism contract: ``source.segment(s, n)`` depends only on the source's
own fields — never on what was generated before — so streaming a prefix and
materializing the same prefix are bit-identical by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packet import PacketBatch, to_time_major
from repro.traffic.generator import Workload, enterprise

__all__ = [
    "TraceSource", "MaterializedSource", "SyntheticSource", "FlowPool",
    "DiurnalLoad", "as_source", "splitmix32",
]


def splitmix32(x: jax.Array) -> jax.Array:
    """Counter-based splitmix mix (32-bit variant): uint32 -> uint32.

    Stateless — hashing a counter IS the RNG stream — which is what lets
    flow identity and reservoir decisions be pure functions of an index
    (no generator state in any carry)."""
    z = (x.astype(jnp.uint32) + jnp.uint32(0x9E3779B9))
    z = (z ^ (z >> 16)) * jnp.uint32(0x85EBCA6B)
    z = (z ^ (z >> 13)) * jnp.uint32(0xC2B2AE35)
    return z ^ (z >> 16)


@dataclasses.dataclass(frozen=True)
class FlowPool:
    """``n_flows`` deterministic (src_ip, src_port) identities, computed on
    the fly from the flow index — no materialized arrays, so the pool can
    be sized for millions of concurrent flows.  Distinct indices may collide
    on IP with probability ~n^2/2^32 (birthday bound; ~0.01 % at 1e3 flows,
    still under 12 % at 1e5) — collisions merely merge two flows' NF state,
    they never corrupt parking."""

    n_flows: int
    seed: int = 7

    def __post_init__(self):
        if self.n_flows < 1:
            raise ValueError(f"n_flows must be >= 1, got {self.n_flows}")

    def identity(self, flow: jax.Array) -> tuple[jax.Array, jax.Array]:
        h = splitmix32(flow.astype(jnp.uint32) ^
                       splitmix32(jnp.uint32(self.seed)))
        h2 = splitmix32(h)
        ip = (h.astype(jnp.int32) & jnp.int32(0x7FFFFFFF)) | jnp.int32(1)
        port = jnp.int32(1024) + (h2.astype(jnp.int32) & jnp.int32(0x7FFF))
        return ip, port


@dataclasses.dataclass(frozen=True)
class DiurnalLoad:
    """Time-varying offered load: ``load(t)`` in [floor, 1] follows one
    sinusoidal "day" of ``period`` steps.  Per step, the first
    ``round(load * chunk)`` rows of the generated chunk are offered; the
    rest are dead (zeroed) rows — geometry stays fixed, only the alive
    prefix breathes.  A pure function of ``t``: replaying a segment
    reproduces its load exactly."""

    period: int = 4096
    base: float = 0.75
    amplitude: float = 0.25
    phase: float = 0.0

    def __post_init__(self):
        if self.period < 1:
            raise ValueError(f"period must be >= 1, got {self.period}")
        if not 0.0 <= self.base - self.amplitude:
            raise ValueError("load floor (base - amplitude) must be >= 0")
        if self.base + self.amplitude > 1.0 + 1e-9:
            raise ValueError("load peak (base + amplitude) must be <= 1")

    def load(self, t: jax.Array) -> jax.Array:
        ang = 2.0 * jnp.pi * (t.astype(jnp.float32) / self.period) + self.phase
        return self.base + self.amplitude * jnp.sin(ang)

    def offered(self, t: jax.Array, chunk: int) -> jax.Array:
        return jnp.round(self.load(t) * chunk).astype(jnp.int32)


class TraceSource:
    """A deterministic recipe for a fixed-geometry time-major trace.

    Contract (DESIGN.md §13): ``chunk``/``pmax`` fix the per-step geometry,
    ``steps`` its length; ``segment(start, count)`` returns the
    (count, chunk, ...) PacketBatch for steps ``[start, start+count)`` and
    must be a pure function of the source's fields — independent of call
    history — so any prefix can be replayed bit-identically."""

    chunk: int
    pmax: int
    steps: int

    @property
    def packets(self) -> int:
        return self.steps * self.chunk

    def segment(self, start: int, count: int) -> PacketBatch:
        raise NotImplementedError

    def __iter__(self) -> Iterator[PacketBatch]:
        for t in range(self.steps):
            yield self.segment(t, 1)

    def materialize(self, steps: int | None = None) -> PacketBatch:
        """The one-shot view: the (steps, chunk, ...) time-major trace the
        materialized engine scans.  Streaming this source and scanning the
        materialization are bit-identical (the replay oracle's invariant)."""
        n = self.steps if steps is None else steps
        if not 0 <= n <= self.steps:
            raise ValueError(f"steps {n} outside [0, {self.steps}]")
        return self.segment(0, n)


@dataclasses.dataclass
class MaterializedSource(TraceSource):
    """The trivial source: an already-built (T, chunk, ...) trace."""

    trace: PacketBatch

    def __post_init__(self):
        leaf = jax.tree.leaves(self.trace)[0]
        self.steps = int(leaf.shape[0])
        self.chunk = int(leaf.shape[1])
        self.pmax = int(self.trace.pmax)

    def segment(self, start: int, count: int) -> PacketBatch:
        if not 0 <= start <= start + count <= self.steps:
            raise ValueError(
                f"segment [{start}, {start + count}) outside "
                f"[0, {self.steps})")
        return jax.tree.map(lambda a: a[start:start + count], self.trace)

    @classmethod
    def from_flat(cls, pkts: PacketBatch, chunk: int) -> "MaterializedSource":
        return cls(to_time_major(pkts, chunk))


@dataclasses.dataclass
class SyntheticSource(TraceSource):
    """Streaming workload generator: chunk ``t`` = f(seed, t).

    Each step folds ``t`` into the base key and draws a fresh ``workload``
    chunk; ``flows`` (a FlowPool or a flow count) rewrites source identity
    from the splitmix pool; ``load`` (optional DiurnalLoad) limits the
    alive prefix and zeroes the dead tail so offered traffic is canonical.
    The per-count segment builder is jitted once per segment length."""

    steps: int
    chunk: int = 256
    pmax: int = 2048
    seed: int = 0
    workload: Workload = None
    flows: "FlowPool | int | None" = None
    load: DiurnalLoad | None = None

    def __post_init__(self):
        if self.steps < 0:
            raise ValueError(f"steps must be >= 0, got {self.steps}")
        if self.workload is None:
            self.workload = enterprise()
        if isinstance(self.flows, int):
            self.flows = FlowPool(self.flows, seed=self.seed + 7)
        self._jit_segment = jax.jit(self._segment, static_argnames="count")

    def _one_step(self, t: jax.Array) -> PacketBatch:
        key = jax.random.fold_in(jax.random.key(self.seed), t)
        pkts = self.workload.make_batch(key, self.chunk, pmax=self.pmax)
        if self.flows is not None:
            kf = jax.random.fold_in(key, 0xF10)
            idx = jax.random.randint(kf, (self.chunk,), 0,
                                     self.flows.n_flows, dtype=jnp.int32)
            ip, port = self.flows.identity(idx)
            pkts = pkts.replace(src_ip=ip, src_port=port)
        if self.load is not None:
            alive = jnp.arange(self.chunk) < self.load.offered(t, self.chunk)
            # zero the dead tail entirely (dead rows are all-zero by
            # convention — see engine ring seeding) so the offered trace
            # is canonical, not just masked
            pkts = jax.tree.map(
                lambda a: jnp.where(
                    alive.reshape((-1,) + (1,) * (a.ndim - 1)), a,
                    jnp.zeros_like(a)), pkts)
        return pkts

    def _segment(self, start, count: int) -> PacketBatch:
        ts = start + jnp.arange(count, dtype=jnp.int32)
        return jax.vmap(self._one_step)(ts)

    def segment(self, start: int, count: int) -> PacketBatch:
        if not 0 <= start <= start + count <= self.steps:
            raise ValueError(
                f"segment [{start}, {start + count}) outside "
                f"[0, {self.steps})")
        return self._jit_segment(jnp.int32(start), count)


def as_source(trace, chunk: int | None = None) -> TraceSource:
    """Coerce the trace spellings every engine entry point accepts:
    a TraceSource passes through; a time-major (T, chunk, ...) PacketBatch
    becomes a MaterializedSource; a flat (B, ...) batch needs ``chunk``."""
    if isinstance(trace, TraceSource):
        return trace
    if isinstance(trace, PacketBatch):
        if trace.src_ip.ndim == 2:
            return MaterializedSource(trace)
        if trace.src_ip.ndim == 1:
            if chunk is None:
                raise ValueError(
                    "flat packet batch needs an explicit chunk size")
            return MaterializedSource.from_flat(trace, chunk)
        raise ValueError(
            f"expected a flat batch or a time-major trace, got a "
            f"{trace.src_ip.ndim}-dim PacketBatch")
    raise TypeError(
        f"trace must be a TraceSource or PacketBatch, got "
        f"{type(trace).__name__}")
