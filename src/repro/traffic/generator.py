"""Workload generation mirroring the paper's PktGen setup (§6.1, Fig. 6).

Two workload families:
  * ``fixed(size)`` — fixed-size UDP packets (256..1492 B sweeps, Figs. 8/9/15/16)
  * ``enterprise()`` — bimodal packet-size distribution reproducing Benson et
    al. [IMC'10] enterprise-datacenter traffic as digitized from the paper's
    Fig. 6: ~30 % of packets carry payloads under 160 B (not splittable) and
    the mean packet size is ~882 B.

Packet sizes are total on-wire bytes including the 42-byte header.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packet import HDR_BYTES, PacketBatch, make_udp_batch

# Digitized bimodal enterprise distribution (paper Fig. 6).  30 % of packets
# are below 202 B total (payload < 160 B -> ENB=0), mean ~= 882 B.
ENTERPRISE_SIZES = np.array([64, 128, 190, 512, 1024, 1492], np.int32)
ENTERPRISE_PROBS = np.array([0.10, 0.12, 0.08, 0.12, 0.18, 0.40])
ENTERPRISE_MEAN = float((ENTERPRISE_SIZES * ENTERPRISE_PROBS).sum())  # ~879.5


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    sizes: np.ndarray   # candidate total packet sizes (bytes)
    probs: np.ndarray   # selection probabilities

    @property
    def mean_pkt_bytes(self) -> float:
        return float((self.sizes * self.probs).sum())

    def sample_sizes(self, key: jax.Array, n: int) -> jax.Array:
        idx = jax.random.choice(
            key, self.sizes.shape[0], (n,), p=jnp.asarray(self.probs))
        return jnp.asarray(self.sizes)[idx]

    def make_batch(self, key: jax.Array, n: int, pmax: int = 2048,
                   **field_overrides) -> PacketBatch:
        k1, k2 = jax.random.split(key)
        sizes = self.sample_sizes(k1, n)
        return make_udp_batch(k2, n, sizes, pmax=pmax, **field_overrides)


def fixed(size: int) -> Workload:
    assert size >= HDR_BYTES
    return Workload(f"fixed{size}", np.array([size], np.int32),
                    np.array([1.0]))


def enterprise() -> Workload:
    return Workload("enterprise", ENTERPRISE_SIZES, ENTERPRISE_PROBS)
