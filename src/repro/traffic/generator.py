"""Workload generation mirroring the paper's PktGen setup (§6.1, Fig. 6).

Three workload families:
  * ``fixed(size)`` — fixed-size UDP packets (256..1492 B sweeps, Figs. 8/9/15/16)
  * ``enterprise()`` — bimodal packet-size distribution reproducing Benson et
    al. [IMC'10] enterprise-datacenter traffic as digitized from the paper's
    Fig. 6: ~30 % of packets carry payloads under 160 B (not splittable) and
    the mean packet size is ~882 B.
  * ``datacenter()`` — the DC-side distribution from the same Benson et al.
    study (the paper §7's "datacenter-characteristic traffic"): strongly
    bimodal at the two extremes — ~45 % of packets are small control/ACK
    traffic under 203 B total (not splittable) and ~45 % ride near the MTU,
    mean ~700 B.  Distinct from ``enterprise()``, whose mass sits in the
    mid sizes; this is the workload the §7 FW->NAT->LB chain headline
    (13 % goodput gain, 28 % with recirculation) is evaluated on.

Packet sizes are total on-wire bytes including the 42-byte header.

``steer_pipes`` is the ingress steering stage for the multi-pipe engine
(DESIGN.md §3): it shards a flat batch across N per-port pipes by a hash of
the flow 5-tuple, the software analogue of the ToR switch mapping each
server-facing port to its own pipeline (§6.3.2).  Flow affinity is exact:
every packet of a 5-tuple lands in the same pipe, so per-pipe NAT/LB state
behaves as it would behind a real port.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packet import (HDR_BYTES, PacketBatch, gather_rows,
                               make_udp_batch)

# Digitized bimodal enterprise distribution (paper Fig. 6).  30 % of packets
# are below 202 B total (payload < 160 B -> ENB=0), mean ~= 882 B.
ENTERPRISE_SIZES = np.array([64, 128, 190, 512, 1024, 1492], np.int32)
ENTERPRISE_PROBS = np.array([0.10, 0.12, 0.08, 0.12, 0.18, 0.40])
ENTERPRISE_MEAN = float((ENTERPRISE_SIZES * ENTERPRISE_PROBS).sum())  # ~879.5

# Benson et al. DC-side distribution (paper §7): mass at the two extremes —
# small control/ACK packets (64..128 B, not splittable) and near-MTU data
# packets; the thin middle is what distinguishes it from the enterprise mix.
DATACENTER_SIZES = np.array([64, 128, 256, 595, 1024, 1492], np.int32)
DATACENTER_PROBS = np.array([0.35, 0.10, 0.05, 0.05, 0.10, 0.35])
DATACENTER_MEAN = float((DATACENTER_SIZES * DATACENTER_PROBS).sum())  # ~702


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    sizes: np.ndarray   # candidate total packet sizes (bytes)
    probs: np.ndarray   # selection probabilities

    @property
    def mean_pkt_bytes(self) -> float:
        return float((self.sizes * self.probs).sum())

    def splittable_share(self, min_park_len: int = 160,
                         park_bytes: int = 160) -> float:
        """Fraction of offered wire bytes Split can park: expected parked
        bytes / expected packet bytes.  The PCIe-load reduction on the NF
        server is monotone in this share (DESIGN.md §7) — it is the
        workload-side knob the host-model benchmark sweeps."""
        parked = sum(
            p * min(s - HDR_BYTES, park_bytes)
            for s, p in zip(self.sizes, self.probs)
            if s - HDR_BYTES >= min_park_len)
        return float(parked) / self.mean_pkt_bytes

    def sample_sizes(self, key: jax.Array, n: int) -> jax.Array:
        idx = jax.random.choice(
            key, self.sizes.shape[0], (n,), p=jnp.asarray(self.probs))
        return jnp.asarray(self.sizes)[idx]

    def make_batch(self, key: jax.Array, n: int, pmax: int = 2048,
                   **field_overrides) -> PacketBatch:
        k1, k2 = jax.random.split(key)
        sizes = self.sample_sizes(k1, n)
        return make_udp_batch(k2, n, sizes, pmax=pmax, **field_overrides)


def fixed(size: int) -> Workload:
    assert size >= HDR_BYTES
    return Workload(f"fixed{size}", np.array([size], np.int32),
                    np.array([1.0]))


def enterprise() -> Workload:
    return Workload("enterprise", ENTERPRISE_SIZES, ENTERPRISE_PROBS)


def datacenter() -> Workload:
    return Workload("datacenter", DATACENTER_SIZES, DATACENTER_PROBS)


def flow_pool(n_flows: int, seed: int = 7) -> tuple[jax.Array, jax.Array]:
    """Deterministic pool of ``n_flows`` distinct (src_ip, src_port) flows.

    Constraining a workload's source identity to a fixed pool (instead of
    the full 2^31 x 64k space) gives scenarios a flow structure: firewall
    rules drawn from the pool IPs drop a controlled traffic share, the NAT
    flow table (keyed on src_ip + src_port) sees repeat flows instead of a
    fresh mapping per packet, and — because the pool depends only on
    ``seed`` — the resulting NF chain is *identical across workloads*,
    which is what lets the scenario runner share one compiled engine
    across workload axes (DESIGN.md §8).

    Returns ``(ips, ports)``, both (n_flows,) int32.
    """
    assert n_flows >= 1
    kip, kport = jax.random.split(jax.random.key(seed))
    ips = jax.random.randint(kip, (n_flows,),
                             1, (1 << 31) - 1, dtype=jnp.int32)
    ports = jax.random.randint(kport, (n_flows,), 1024, 65536,
                               dtype=jnp.int32)
    # IP collisions are astronomically unlikely but would silently merge
    # flows (port collisions across distinct IPs are fine)
    assert int(jnp.unique(ips).shape[0]) == n_flows
    return ips, ports


# --------------------------------------------------------------------------
# Multi-pipe ingress steering (DESIGN.md §3)
# --------------------------------------------------------------------------

def flow_hash(pkts: PacketBatch) -> jax.Array:
    """Avalanche hash of the flow 5-tuple, (B,) non-negative int32.

    Built from the same murmur3-finalizer constants as the NAT flow-table
    hash (but over the full 5-tuple, with its own mixing sequence — the two
    are not bit-compatible); a switch would compute this with its hash
    engine over the same header fields.
    """
    h = pkts.src_ip ^ jnp.int32(-1640531527)
    h = (h * jnp.int32(-2048144789)) ^ pkts.dst_ip
    h = h ^ (h >> 13)
    h = (h * jnp.int32(-1028477379)) ^ (pkts.src_port << 16) ^ pkts.dst_port
    h = h ^ (h >> 16)
    h = (h * jnp.int32(-2048144789)) ^ pkts.proto
    h = h ^ (h >> 13)
    return h & jnp.int32(0x7FFFFFFF)


def steer_pipes(
    pkts: PacketBatch,
    num_pipes: int,
    pipe_capacity: int | None = None,
    chunk: int = 256,
) -> tuple[PacketBatch, dict]:
    """Shard a flat batch into per-pipe batches by flow hash.

    Returns ``(shards, stats)`` where ``shards`` leaves have shape
    (num_pipes, pipe_capacity, ...).  Slots beyond a pipe's arrival count
    are dead packets; arrivals beyond ``pipe_capacity`` (hash skew) are
    dropped and counted in ``stats['overflow']`` — the analogue of an
    ingress-port queue overrunning.  ``pipe_capacity`` defaults to ~1.25x
    the fair share, rounded up to a multiple of ``chunk`` so the result
    feeds ``core.packet.to_time_major`` directly.

    Packet order within a pipe preserves arrival order, so single-pipe
    steering (num_pipes=1) is the identity modulo tail padding.
    """
    b = pkts.batch_size
    pipe = flow_hash(pkts) % num_pipes                      # (B,)
    if pipe_capacity is None:
        fair = -(-b // num_pipes)                           # ceil
        slack = fair if num_pipes == 1 else (fair * 5) // 4
        pipe_capacity = -(-slack // chunk) * chunk          # round to chunk
    onehot = pipe[:, None] == jnp.arange(num_pipes)[None, :]  # (B, P)
    pos = jnp.cumsum(onehot, axis=0) - 1                    # arrival index
    pos = jnp.take_along_axis(pos, pipe[:, None], axis=1)[:, 0]
    ok = pos < pipe_capacity
    dest = jnp.where(ok, pipe * pipe_capacity + pos,
                     num_pipes * pipe_capacity)
    # Invert the permutation: src_of[dest] = packet row; empty slots -> B,
    # which gather_rows maps to a dead packet.
    src_of = jnp.full((num_pipes * pipe_capacity,), b, jnp.int32)
    src_of = src_of.at[dest].set(jnp.arange(b, dtype=jnp.int32), mode="drop")
    shards = gather_rows(pkts, src_of)
    shards = jax.tree.map(
        lambda a: a.reshape((num_pipes, pipe_capacity) + a.shape[1:]), shards)
    counts = jnp.sum(onehot, axis=0)
    stats = dict(
        per_pipe_arrivals=[int(c) for c in counts],
        overflow=int(jnp.sum(~ok)),
        pipe_capacity=pipe_capacity,
    )
    return shards, stats
