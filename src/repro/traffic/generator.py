"""Workload generation mirroring the paper's PktGen setup (§6.1, Fig. 6).

Two workload families:
  * ``fixed(size)`` — fixed-size UDP packets (256..1492 B sweeps, Figs. 8/9/15/16)
  * ``enterprise()`` — bimodal packet-size distribution reproducing Benson et
    al. [IMC'10] enterprise-datacenter traffic as digitized from the paper's
    Fig. 6: ~30 % of packets carry payloads under 160 B (not splittable) and
    the mean packet size is ~882 B.

Packet sizes are total on-wire bytes including the 42-byte header.

``steer_pipes`` is the ingress steering stage for the multi-pipe engine
(DESIGN.md §3): it shards a flat batch across N per-port pipes by a hash of
the flow 5-tuple, the software analogue of the ToR switch mapping each
server-facing port to its own pipeline (§6.3.2).  Flow affinity is exact:
every packet of a 5-tuple lands in the same pipe, so per-pipe NAT/LB state
behaves as it would behind a real port.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packet import (HDR_BYTES, PacketBatch, gather_rows,
                               make_udp_batch)

# Digitized bimodal enterprise distribution (paper Fig. 6).  30 % of packets
# are below 202 B total (payload < 160 B -> ENB=0), mean ~= 882 B.
ENTERPRISE_SIZES = np.array([64, 128, 190, 512, 1024, 1492], np.int32)
ENTERPRISE_PROBS = np.array([0.10, 0.12, 0.08, 0.12, 0.18, 0.40])
ENTERPRISE_MEAN = float((ENTERPRISE_SIZES * ENTERPRISE_PROBS).sum())  # ~879.5


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    sizes: np.ndarray   # candidate total packet sizes (bytes)
    probs: np.ndarray   # selection probabilities

    @property
    def mean_pkt_bytes(self) -> float:
        return float((self.sizes * self.probs).sum())

    def splittable_share(self, min_park_len: int = 160,
                         park_bytes: int = 160) -> float:
        """Fraction of offered wire bytes Split can park: expected parked
        bytes / expected packet bytes.  The PCIe-load reduction on the NF
        server is monotone in this share (DESIGN.md §7) — it is the
        workload-side knob the host-model benchmark sweeps."""
        parked = sum(
            p * min(s - HDR_BYTES, park_bytes)
            for s, p in zip(self.sizes, self.probs)
            if s - HDR_BYTES >= min_park_len)
        return float(parked) / self.mean_pkt_bytes

    def sample_sizes(self, key: jax.Array, n: int) -> jax.Array:
        idx = jax.random.choice(
            key, self.sizes.shape[0], (n,), p=jnp.asarray(self.probs))
        return jnp.asarray(self.sizes)[idx]

    def make_batch(self, key: jax.Array, n: int, pmax: int = 2048,
                   **field_overrides) -> PacketBatch:
        k1, k2 = jax.random.split(key)
        sizes = self.sample_sizes(k1, n)
        return make_udp_batch(k2, n, sizes, pmax=pmax, **field_overrides)


def fixed(size: int) -> Workload:
    assert size >= HDR_BYTES
    return Workload(f"fixed{size}", np.array([size], np.int32),
                    np.array([1.0]))


def enterprise() -> Workload:
    return Workload("enterprise", ENTERPRISE_SIZES, ENTERPRISE_PROBS)


# --------------------------------------------------------------------------
# Multi-pipe ingress steering (DESIGN.md §3)
# --------------------------------------------------------------------------

def flow_hash(pkts: PacketBatch) -> jax.Array:
    """Avalanche hash of the flow 5-tuple, (B,) non-negative int32.

    Built from the same murmur3-finalizer constants as the NAT flow-table
    hash (but over the full 5-tuple, with its own mixing sequence — the two
    are not bit-compatible); a switch would compute this with its hash
    engine over the same header fields.
    """
    h = pkts.src_ip ^ jnp.int32(-1640531527)
    h = (h * jnp.int32(-2048144789)) ^ pkts.dst_ip
    h = h ^ (h >> 13)
    h = (h * jnp.int32(-1028477379)) ^ (pkts.src_port << 16) ^ pkts.dst_port
    h = h ^ (h >> 16)
    h = (h * jnp.int32(-2048144789)) ^ pkts.proto
    h = h ^ (h >> 13)
    return h & jnp.int32(0x7FFFFFFF)


def steer_pipes(
    pkts: PacketBatch,
    num_pipes: int,
    pipe_capacity: int | None = None,
    chunk: int = 256,
) -> tuple[PacketBatch, dict]:
    """Shard a flat batch into per-pipe batches by flow hash.

    Returns ``(shards, stats)`` where ``shards`` leaves have shape
    (num_pipes, pipe_capacity, ...).  Slots beyond a pipe's arrival count
    are dead packets; arrivals beyond ``pipe_capacity`` (hash skew) are
    dropped and counted in ``stats['overflow']`` — the analogue of an
    ingress-port queue overrunning.  ``pipe_capacity`` defaults to ~1.25x
    the fair share, rounded up to a multiple of ``chunk`` so the result
    feeds ``core.packet.to_time_major`` directly.

    Packet order within a pipe preserves arrival order, so single-pipe
    steering (num_pipes=1) is the identity modulo tail padding.
    """
    b = pkts.batch_size
    pipe = flow_hash(pkts) % num_pipes                      # (B,)
    if pipe_capacity is None:
        fair = -(-b // num_pipes)                           # ceil
        slack = fair if num_pipes == 1 else (fair * 5) // 4
        pipe_capacity = -(-slack // chunk) * chunk          # round to chunk
    onehot = pipe[:, None] == jnp.arange(num_pipes)[None, :]  # (B, P)
    pos = jnp.cumsum(onehot, axis=0) - 1                    # arrival index
    pos = jnp.take_along_axis(pos, pipe[:, None], axis=1)[:, 0]
    ok = pos < pipe_capacity
    dest = jnp.where(ok, pipe * pipe_capacity + pos,
                     num_pipes * pipe_capacity)
    # Invert the permutation: src_of[dest] = packet row; empty slots -> B,
    # which gather_rows maps to a dead packet.
    src_of = jnp.full((num_pipes * pipe_capacity,), b, jnp.int32)
    src_of = src_of.at[dest].set(jnp.arange(b, dtype=jnp.int32), mode="drop")
    shards = gather_rows(pkts, src_of)
    shards = jax.tree.map(
        lambda a: a.reshape((num_pipes, pipe_capacity) + a.shape[1:]), shards)
    counts = jnp.sum(onehot, axis=0)
    stats = dict(
        per_pipe_arrivals=[int(c) for c in counts],
        overflow=int(jnp.sum(~ok)),
        pipe_capacity=pipe_capacity,
    )
    return shards, stats
