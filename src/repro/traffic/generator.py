"""Workload generation mirroring the paper's PktGen setup (§6.1, Fig. 6).

Three workload families:
  * ``fixed(size)`` — fixed-size UDP packets (256..1492 B sweeps, Figs. 8/9/15/16)
  * ``enterprise()`` — bimodal packet-size distribution reproducing Benson et
    al. [IMC'10] enterprise-datacenter traffic as digitized from the paper's
    Fig. 6: ~30 % of packets carry payloads under 160 B (not splittable) and
    the mean packet size is ~882 B.
  * ``datacenter()`` — the DC-side distribution from the same Benson et al.
    study (the paper §7's "datacenter-characteristic traffic"): strongly
    bimodal at the two extremes — ~45 % of packets are small control/ACK
    traffic under 203 B total (not splittable) and ~45 % ride near the MTU,
    mean ~700 B.  Distinct from ``enterprise()``, whose mass sits in the
    mid sizes; this is the workload the §7 FW->NAT->LB chain headline
    (13 % goodput gain, 28 % with recirculation) is evaluated on.

Packet sizes are total on-wire bytes including the 42-byte header.

``steer_pipes`` is the ingress steering stage for the multi-pipe engine
(DESIGN.md §3): it shards a flat batch across N per-port pipes by a hash of
the flow 5-tuple, the software analogue of the ToR switch mapping each
server-facing port to its own pipeline (§6.3.2).  Flow affinity is exact:
every packet of a 5-tuple lands in the same pipe, so per-pipe NAT/LB state
behaves as it would behind a real port.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packet import (HDR_BYTES, PacketBatch, gather_rows,
                               make_udp_batch)

# Digitized bimodal enterprise distribution (paper Fig. 6).  30 % of packets
# are below 202 B total (payload < 160 B -> ENB=0), mean ~= 882 B.
ENTERPRISE_SIZES = np.array([64, 128, 190, 512, 1024, 1492], np.int32)
ENTERPRISE_PROBS = np.array([0.10, 0.12, 0.08, 0.12, 0.18, 0.40])
ENTERPRISE_MEAN = float((ENTERPRISE_SIZES * ENTERPRISE_PROBS).sum())  # ~879.5

# Benson et al. DC-side distribution (paper §7): mass at the two extremes —
# small control/ACK packets (64..128 B, not splittable) and near-MTU data
# packets; the thin middle is what distinguishes it from the enterprise mix.
DATACENTER_SIZES = np.array([64, 128, 256, 595, 1024, 1492], np.int32)
DATACENTER_PROBS = np.array([0.35, 0.10, 0.05, 0.05, 0.10, 0.35])
DATACENTER_MEAN = float((DATACENTER_SIZES * DATACENTER_PROBS).sum())  # ~702


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    sizes: np.ndarray   # candidate total packet sizes (bytes)
    probs: np.ndarray   # selection probabilities

    @property
    def mean_pkt_bytes(self) -> float:
        return float((self.sizes * self.probs).sum())

    def splittable_share(self, min_park_len: int = 160,
                         park_bytes: int = 160) -> float:
        """Fraction of offered wire bytes Split can park: expected parked
        bytes / expected packet bytes.  The PCIe-load reduction on the NF
        server is monotone in this share (DESIGN.md §7) — it is the
        workload-side knob the host-model benchmark sweeps."""
        parked = sum(
            p * min(s - HDR_BYTES, park_bytes)
            for s, p in zip(self.sizes, self.probs)
            if s - HDR_BYTES >= min_park_len)
        return float(parked) / self.mean_pkt_bytes

    def sample_sizes(self, key: jax.Array, n: int) -> jax.Array:
        idx = jax.random.choice(
            key, self.sizes.shape[0], (n,), p=jnp.asarray(self.probs))
        return jnp.asarray(self.sizes)[idx]

    def make_batch(self, key: jax.Array, n: int, pmax: int = 2048,
                   **field_overrides) -> PacketBatch:
        k1, k2 = jax.random.split(key)
        sizes = self.sample_sizes(k1, n)
        return make_udp_batch(k2, n, sizes, pmax=pmax, **field_overrides)


def fixed(size: int) -> Workload:
    assert size >= HDR_BYTES
    return Workload(f"fixed{size}", np.array([size], np.int32),
                    np.array([1.0]))


# --------------------------------------------------------------------------
# Adversarial & churn workloads (DESIGN.md §10)
# --------------------------------------------------------------------------

# Attack packets spoof the source but converge on one victim service —
# classic SYN-flood shape, sized just past the parking threshold so every
# attack packet CLAIMS a table slot while parking almost no useful bytes.
VICTIM_IP = 0x0A00FFFE
VICTIM_PORT = 80
ATTACK_SIZE = 208  # 166 B payload: minimally splittable (>= 160 + HDR 42)


@dataclasses.dataclass(frozen=True)
class AdversarialWorkload(Workload):
    """Base traffic with a burst-structured small-packet storm overlaid.

    ``attack_fraction`` of the per-batch *burst slots* (contiguous
    ``burst``-packet runs) are replaced by attack packets: spoofed random
    sources, one victim destination, ``attack_size`` bytes total — just
    splittable, so each one claims a parking slot for a 160-byte payload
    and evicts legitimate large-packet state under pressure.

    Attack-slot placement is COUPLED across fractions: each burst slot
    draws one permutation rank from the key, and a slot attacks iff its
    rank falls below ``attack_fraction``'s cut.  Raising the fraction only
    *adds* attack slots (never moves them), which is what makes drop rate
    provably monotone in attack load for the property tests, and
    ``attack_fraction=0`` is bit-identical to the base workload.
    """

    base: Workload = None
    attack_fraction: float = 0.0
    burst: int = 32
    attack_size: int = ATTACK_SIZE

    def make_batch(self, key: jax.Array, n: int, pmax: int = 2048,
                   **field_overrides) -> PacketBatch:
        k1, k2 = jax.random.split(key)
        sizes = self.base.sample_sizes(k1, n)
        km, kip, kport = jax.random.split(
            jax.random.fold_in(key, 0x5ADF), 3)
        n_slots = -(-n // self.burst)
        rank = jax.random.permutation(km, n_slots)
        n_attack = int(round(self.attack_fraction * n_slots))
        mask = rank[jnp.arange(n) // self.burst] < n_attack
        sizes = jnp.where(mask, self.attack_size, sizes)
        pkts = make_udp_batch(k2, n, sizes, pmax=pmax, **field_overrides)
        spoof_ip = jax.random.randint(kip, (n,), 1 << 28, (1 << 31) - 1,
                                      dtype=jnp.int32)
        spoof_port = jax.random.randint(kport, (n,), 1024, 65536,
                                        dtype=jnp.int32)
        return pkts.replace(
            src_ip=jnp.where(mask, spoof_ip, pkts.src_ip),
            src_port=jnp.where(mask, spoof_port, pkts.src_port),
            dst_ip=jnp.where(mask, jnp.int32(VICTIM_IP), pkts.dst_ip),
            dst_port=jnp.where(mask, jnp.int32(VICTIM_PORT), pkts.dst_port),
        )


def adversarial(base: str | Workload = "enterprise",
                attack_fraction: float = 0.5, burst: int = 32,
                attack_size: int = ATTACK_SIZE) -> AdversarialWorkload:
    """Small-packet-storm workload (attack-fraction x burst axes)."""
    if isinstance(base, str):
        base = {"enterprise": enterprise, "datacenter": datacenter}[base]()
    frac = float(attack_fraction)
    if not 0.0 <= frac <= 1.0:
        raise ValueError(f"attack_fraction must be in [0, 1], got {frac}")
    if burst < 1:
        raise ValueError(f"burst must be >= 1, got {burst}")
    if attack_size - HDR_BYTES < 160:
        raise ValueError(
            f"attack_size {attack_size} is not splittable (payload < 160)")
    # mixture view for the analytic helpers (mean bytes, splittable share)
    sizes = np.append(base.sizes, np.int32(attack_size))
    probs = np.append(base.probs * (1.0 - frac), frac)
    return AdversarialWorkload(
        name=f"adv_{base.name}_f{int(round(frac * 100)):02d}_b{burst}",
        sizes=sizes, probs=probs, base=base, attack_fraction=frac,
        burst=int(burst), attack_size=int(attack_size))


def _flow_identity(flow: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Deterministic flow index -> (src_ip, src_port), murmur-style mix."""
    h = flow.astype(jnp.int32) * jnp.int32(-2048144789)
    h = h ^ (h >> 13)
    h = h * jnp.int32(-1028477379)
    h = h ^ (h >> 16)
    ip = (h & jnp.int32(0x7FFFFFFF)) | jnp.int32(1)
    port = jnp.int32(1024) + ((h >> 7) & jnp.int32(0x7FFF))
    return ip, port


@dataclasses.dataclass(frozen=True)
class ChurnWorkload(Workload):
    """Base traffic whose flow population slides over time.

    Packets draw flows uniformly from a ``pool``-wide window that advances
    by ``pool // 2`` every ``rotate`` packets (half-overlapping windows):
    every flow stays active across two windows and then never returns.
    With a NAT table smaller than the live window this sustains CLOCK
    aging — mappings age out *while their flows are still sending*, which
    is exactly the stale-mapping edge case ``nat_stale_hits`` counts.
    """

    base: Workload = None
    pool: int = 256
    rotate: int = 1024

    def make_batch(self, key: jax.Array, n: int, pmax: int = 2048,
                   **field_overrides) -> PacketBatch:
        k1, k2 = jax.random.split(key)
        sizes = self.base.sample_sizes(k1, n)
        pkts = make_udp_batch(k2, n, sizes, pmax=pmax, **field_overrides)
        ku = jax.random.fold_in(key, 0xC4)
        u = jax.random.randint(ku, (n,), 0, self.pool, dtype=jnp.int32)
        win = (jnp.arange(n, dtype=jnp.int32) // self.rotate)
        flow = win * (self.pool // 2) + u
        ip, port = _flow_identity(flow)
        return pkts.replace(src_ip=ip, src_port=port)


def churn(pool: int = 256, rotate: int = 1024,
          base: str | Workload = "enterprise") -> ChurnWorkload:
    """Sustained flow-churn workload (NAT CLOCK-aging pressure)."""
    if isinstance(base, str):
        base = {"enterprise": enterprise, "datacenter": datacenter}[base]()
    if pool < 2 or rotate < 1:
        raise ValueError(f"need pool >= 2 and rotate >= 1, got "
                         f"({pool}, {rotate})")
    return ChurnWorkload(
        name=f"churn_{base.name}_p{pool}_r{rotate}", sizes=base.sizes,
        probs=base.probs, base=base, pool=int(pool), rotate=int(rotate))


def enterprise() -> Workload:
    return Workload("enterprise", ENTERPRISE_SIZES, ENTERPRISE_PROBS)


def datacenter() -> Workload:
    return Workload("datacenter", DATACENTER_SIZES, DATACENTER_PROBS)


def flow_pool(n_flows: int, seed: int = 7) -> tuple[jax.Array, jax.Array]:
    """Deterministic pool of ``n_flows`` distinct (src_ip, src_port) flows.

    Constraining a workload's source identity to a fixed pool (instead of
    the full 2^31 x 64k space) gives scenarios a flow structure: firewall
    rules drawn from the pool IPs drop a controlled traffic share, the NAT
    flow table (keyed on src_ip + src_port) sees repeat flows instead of a
    fresh mapping per packet, and — because the pool depends only on
    ``seed`` — the resulting NF chain is *identical across workloads*,
    which is what lets the scenario runner share one compiled engine
    across workload axes (DESIGN.md §8).

    Returns ``(ips, ports)``, both (n_flows,) int32.
    """
    assert n_flows >= 1
    kip, kport = jax.random.split(jax.random.key(seed))
    ips = jax.random.randint(kip, (n_flows,),
                             1, (1 << 31) - 1, dtype=jnp.int32)
    ports = jax.random.randint(kport, (n_flows,), 1024, 65536,
                               dtype=jnp.int32)
    # IP collisions are astronomically unlikely but would silently merge
    # flows (port collisions across distinct IPs are fine)
    assert int(jnp.unique(ips).shape[0]) == n_flows
    return ips, ports


# --------------------------------------------------------------------------
# Multi-pipe ingress steering (DESIGN.md §3)
# --------------------------------------------------------------------------

def flow_hash(pkts: PacketBatch) -> jax.Array:
    """Avalanche hash of the flow 5-tuple, (B,) non-negative int32.

    Built from the same murmur3-finalizer constants as the NAT flow-table
    hash (but over the full 5-tuple, with its own mixing sequence — the two
    are not bit-compatible); a switch would compute this with its hash
    engine over the same header fields.
    """
    h = pkts.src_ip ^ jnp.int32(-1640531527)
    h = (h * jnp.int32(-2048144789)) ^ pkts.dst_ip
    h = h ^ (h >> 13)
    h = (h * jnp.int32(-1028477379)) ^ (pkts.src_port << 16) ^ pkts.dst_port
    h = h ^ (h >> 16)
    h = (h * jnp.int32(-2048144789)) ^ pkts.proto
    h = h ^ (h >> 13)
    return h & jnp.int32(0x7FFFFFFF)


def pipe_trace_steps(packets: int, pipes: int, chunk: int) -> int:
    """Per-pipe engine steps after §6.3.2 steering — mirrors
    ``steer_pipes``'s default pipe-capacity rounding (~1.25x fair share,
    rounded up to ``chunk``).  Fault windows (``switchsim.faults``) are
    indexed in these per-pipe steps; ``ScenarioSpec`` validates fault
    timing against this."""
    if pipes == 1:
        return packets // chunk
    fair = -(-packets // pipes)
    slack = (fair * 5) // 4
    return -(-slack // chunk)


def steer_pipes(
    pkts: PacketBatch,
    num_pipes: int,
    pipe_capacity: int | None = None,
    chunk: int = 256,
) -> tuple[PacketBatch, dict]:
    """Shard a flat batch into per-pipe batches by flow hash.

    Returns ``(shards, stats)`` where ``shards`` leaves have shape
    (num_pipes, pipe_capacity, ...).  Slots beyond a pipe's arrival count
    are dead packets; arrivals beyond ``pipe_capacity`` (hash skew) are
    dropped and counted in ``stats['overflow']`` — the analogue of an
    ingress-port queue overrunning.  ``pipe_capacity`` defaults to ~1.25x
    the fair share, rounded up to a multiple of ``chunk`` so the result
    feeds ``core.packet.to_time_major`` directly.

    Packet order within a pipe preserves arrival order, so single-pipe
    steering (num_pipes=1) is the identity modulo tail padding.
    """
    b = pkts.batch_size
    pipe = flow_hash(pkts) % num_pipes                      # (B,)
    if pipe_capacity is None:
        fair = -(-b // num_pipes)                           # ceil
        slack = fair if num_pipes == 1 else (fair * 5) // 4
        pipe_capacity = -(-slack // chunk) * chunk          # round to chunk
    onehot = pipe[:, None] == jnp.arange(num_pipes)[None, :]  # (B, P)
    pos = jnp.cumsum(onehot, axis=0) - 1                    # arrival index
    pos = jnp.take_along_axis(pos, pipe[:, None], axis=1)[:, 0]
    ok = pos < pipe_capacity
    dest = jnp.where(ok, pipe * pipe_capacity + pos,
                     num_pipes * pipe_capacity)
    # Invert the permutation: src_of[dest] = packet row; empty slots -> B,
    # which gather_rows maps to a dead packet.
    src_of = jnp.full((num_pipes * pipe_capacity,), b, jnp.int32)
    src_of = src_of.at[dest].set(jnp.arange(b, dtype=jnp.int32), mode="drop")
    shards = gather_rows(pkts, src_of)
    shards = jax.tree.map(
        lambda a: a.reshape((num_pipes, pipe_capacity) + a.shape[1:]), shards)
    counts = jnp.sum(onehot, axis=0)
    stats = dict(
        per_pipe_arrivals=[int(c) for c in counts],
        overflow=int(jnp.sum(~ok)),
        pipe_capacity=pipe_capacity,
    )
    return shards, stats
