"""Mamba-2 SSD (state-space duality) block.

Chunked SSD algorithm (Dao & Gu, arXiv:2405.21060): the sequence is processed
in chunks of Q tokens; within a chunk the quadratic (dual) form computes
Y_diag with a decay-masked C·Bᵀ score matrix, while a tiny sequential scan
over chunk states (B, H, N, P) carries information across chunks:

    Y = Y_diag(intra-chunk, matmul-heavy -> MXU)
      + C_c · h_c (inter-chunk, decayed initial state)

We scan over chunks with ``lax.scan`` so peak memory is one chunk's score
tile (B, H, Q, Q) rather than the full (S/Q, H, Q, Q) stack.  Decode carries
(conv windows, state (B,H,N,P)) — constant-size, hence `long_500k`-capable.

Projections are split per component (z / x / B / C / dt) instead of one fused
in_proj so tensor-parallel sharding is clean: z, x and dt shard over heads
("model" axis) and the per-head SSD scan runs fully head-parallel — the SSM
analogue of megatron attention-head sharding (DESIGN.md §5).  B and C are
group-shared (n_groups=1) and stay replicated.

Single group (n_groups=1): B and C are shared across heads, as in the
mamba2-1.3b config.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm


def ssd_init(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nheads = d_in // s.head_dim
    gn = s.n_groups * s.d_state
    ks = jax.random.split(key, 8)
    return {
        "w_z": cm.ninit(ks[0], (d, d_in), d ** -0.5),
        "w_x": cm.ninit(ks[1], (d, d_in), d ** -0.5),
        "w_b": cm.ninit(ks[2], (d, gn), d ** -0.5),
        "w_c": cm.ninit(ks[3], (d, gn), d ** -0.5),
        "w_dt": cm.ninit(ks[4], (d, nheads), d ** -0.5),
        "conv_x": cm.ninit(ks[5], (s.conv_width, d_in), s.conv_width ** -0.5),
        "conv_x_b": cm.zeros((d_in,)),
        "conv_b": cm.ninit(ks[6], (s.conv_width, gn), s.conv_width ** -0.5),
        "conv_b_b": cm.zeros((gn,)),
        "conv_c": cm.ninit(ks[7], (s.conv_width, gn), s.conv_width ** -0.5),
        "conv_c_b": cm.zeros((gn,)),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "norm": cm.ones((d_in,)),
        "out_proj": cm.ninit(ks[0], (d_in, d), d_in ** -0.5),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv + SiLU.  x: (B,S,C); state: (B,W-1,C)."""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i: i + x.shape[1]] * w[width - 1 - i] for i in range(width))
    return jax.nn.silu(y + b), xp[:, -(width - 1):]


def _project(p, x, cfg: ModelConfig, conv_state):
    """Shared projection path.  Returns (z, xh, bmat, cmat, dt, conv_state)."""
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    cs = conv_state or {}
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    xs, cx = _causal_conv(jnp.einsum("bsd,de->bse", x, p["w_x"]),
                          p["conv_x"], p["conv_x_b"], cs.get("x"))
    bmat, cb = _causal_conv(jnp.einsum("bsd,dn->bsn", x, p["w_b"]),
                            p["conv_b"], p["conv_b_b"], cs.get("b"))
    cmat, cc = _causal_conv(jnp.einsum("bsd,dn->bsn", x, p["w_c"]),
                            p["conv_c"], p["conv_c_b"], cs.get("c"))
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"])
    bsz, slen = x.shape[:2]
    xh = xs.reshape(bsz, slen, nheads, s.head_dim)
    return z, xh, bmat, cmat, dt, {"x": cx, "b": cb, "c": cc}


def ssd_seq(p, x, cfg: ModelConfig, conv_state=None, h0=None, unroll=False):
    """Full-sequence SSD.  x: (B,S,D) -> (y (B,S,D), (h_last, conv_state)).
    ``unroll=True``: Python loop over chunks (dry-run accounting pass)."""
    s = cfg.ssm
    bsz, slen0, _ = x.shape
    q = min(s.chunk, slen0)
    pad = (-slen0) % q
    if pad:
        # Right-pad to a chunk multiple; padded steps only decay the carried
        # state, so outputs for real positions are exact (causal).  Callers
        # that keep the state (prefill) always use chunk-aligned lengths.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    slen = slen0 + pad
    nc = slen // q

    z, xh, bmat, cmat, dt, conv_state = _project(p, x, cfg, conv_state)
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    hdim = s.head_dim
    xh = xh.astype(jnp.float32)
    bmat = bmat.reshape(bsz, slen, s.d_state).astype(jnp.float32)   # G=1
    cmat = cmat.reshape(bsz, slen, s.d_state).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # (B,S,H)
    a = -jnp.exp(p["a_log"])                                        # (H,)
    da = dt * a                                                     # (B,S,H)
    xdt = xh * dt[..., None]                                        # (B,S,H,P)

    # chunked layout
    dac = da.reshape(bsz, nc, q, nheads)
    xc = xdt.reshape(bsz, nc, q, nheads, hdim)
    bc = bmat.reshape(bsz, nc, q, s.d_state)
    cc = cmat.reshape(bsz, nc, q, s.d_state)
    cums = jnp.cumsum(dac, axis=2)                                  # (B,C,Q,H)

    if h0 is None:
        h0 = jnp.zeros((bsz, nheads, s.d_state, hdim), jnp.float32)

    def chunk_step(h, inputs):
        cums_c, xc_c, bc_c, cc_c = inputs
        # intra-chunk decay mask: L[q1,q2] = exp(cums[q1]-cums[q2]), q1>=q2.
        # Mask BEFORE exp: above-diagonal entries are positive and overflow,
        # and where(mask, inf, 0) still propagates NaN gradients.
        seg = cums_c[:, :, None, :] - cums_c[:, None, :, :]         # (B,Q,Q,H)
        tri = jnp.tril(jnp.ones((q, q), bool))
        l_mask = jnp.exp(jnp.where(tri[None, :, :, None], seg, -1e30))
        scores = jnp.einsum("bqn,bkn->bqk", cc_c, bc_c)             # (B,Q,Q)
        y_diag = jnp.einsum("bqk,bqkh,bkhp->bqhp", scores, l_mask, xc_c)
        # contribution of the carried state
        decay_in = jnp.exp(cums_c)                                  # (B,Q,H)
        y_off = jnp.einsum("bqn,bhnp,bqh->bqhp", cc_c, h, decay_in)
        # state update: h' = decay_all * h + sum_k B_k ⊗ x_k decay_to_end
        decay_all = jnp.exp(cums_c[:, -1])                          # (B,H)
        decay_out = jnp.exp(cums_c[:, -1:, :] - cums_c)             # (B,Q,H)
        states = jnp.einsum("bkn,bkh,bkhp->bhnp", bc_c, decay_out, xc_c)
        h_new = decay_all[:, :, None, None] * h + states
        return h_new, y_diag + y_off

    def swap(t):
        return jnp.moveaxis(t, 1, 0)
    if unroll:
        h_last = h0
        ys = []
        for c in range(nc):
            h_last, yo = chunk_step(
                h_last, (cums[:, c], xc[:, c], bc[:, c], cc[:, c]))
            ys.append(yo)
        yc = jnp.stack(ys)
    else:
        h_last, yc = jax.lax.scan(
            chunk_step, h0, (swap(cums), swap(xc), swap(bc), swap(cc)))
    y = jnp.moveaxis(yc, 0, 1).reshape(bsz, slen, nheads, hdim)
    y = y + p["d_skip"][:, None] * xh                               # D skip
    y = y.reshape(bsz, slen, d_in).astype(x.dtype)
    y = cm.rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)     # gated norm
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if pad:
        out = out[:, :-pad]
    return out, (h_last, conv_state)


def ssd_step(p, x, cfg: ModelConfig, state):
    """Single-token decode.  x: (B,1,D); state = (h (B,H,N,P) f32, conv)."""
    s = cfg.ssm
    h_prev, conv_state = state
    z, xh, bmat, cmat, dt, conv_state = _project(p, x, cfg, conv_state)
    d_in = s.expand * cfg.d_model
    xh = xh[:, 0].astype(jnp.float32)                               # (B,H,P)
    bv = bmat[:, 0].astype(jnp.float32)                             # (B,N)
    cv = cmat[:, 0].astype(jnp.float32)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dtv * a)                                        # (B,H)
    h = decay[:, :, None, None] * h_prev + jnp.einsum(
        "bn,bh,bhp->bhnp", bv, dtv, xh)
    y = jnp.einsum("bn,bhnp->bhp", cv, h) + p["d_skip"][:, None] * xh
    y = y.reshape(-1, 1, d_in).astype(x.dtype)
    y = cm.rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), (h, conv_state)
