"""Top-k routed Mixture-of-Experts with dispatch/combine einsums.

Mesh-TensorFlow-style dense dispatch: tokens are processed in groups of
``group_tokens``; each group builds a (T, X, C) dispatch tensor (X experts,
C capacity slots) and the expert FFN runs as batched einsums over the expert
dimension.  Two sharding regimes (DESIGN.md §5):

  * EP   (deepseek-v2, 160 experts): expert dim sharded over "model"; the
    dispatch einsum's contraction over sharded X lowers to the all-to-all-like
    collective pattern GSPMD emits for expert parallelism.
  * TP   (mixtral, 8 experts < mesh axis): experts replicated, expert FFN
    hidden dim sharded over "model" (megatron-style inside each expert).

The choice is made in distributed/sharding.py from num_experts vs axis size;
this module is sharding-agnostic.

Router: softmax probabilities, top-k selection, renormalized weights (the
mixtral convention; deepseek-v2's grouped routing reduces to the same compute
shape — noted in DESIGN.md).  A switch-style load-balance aux loss is
returned for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import common as cm


def moe_init(key, cfg: ModelConfig):
    mo = cfg.moe
    d, f, x = cfg.d_model, mo.d_ff_expert, mo.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": cm.ninit(ks[0], (d, x), d ** -0.5, jnp.float32),
        "wi": cm.ninit(ks[1], (x, d, f), d ** -0.5),
        "wg": cm.ninit(ks[2], (x, d, f), d ** -0.5),
        "wo": cm.ninit(ks[3], (x, f, d), f ** -0.5),
    }
    if mo.shared_experts:
        p["shared"] = cm.mlp_init(ks[4], d, f * mo.shared_experts)
    return p


def _capacity(mo: MoEConfig, group_tokens: int) -> int:
    c = int(mo.capacity_factor * group_tokens * mo.top_k / mo.num_experts)
    return max(8, (c + 7) // 8 * 8)  # pad for lane alignment


def moe_apply(p, x, cfg: ModelConfig, act: str):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    mo = cfg.moe
    b, s, d = x.shape
    tg = min(mo.group_tokens, b * s)
    while (b * s) % tg:  # largest divisor of b*s not exceeding group_tokens
        tg -= 1
    g = b * s // tg
    xt = x.reshape(g, tg, d)

    logits = jnp.einsum("gtd,dx->gtx", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                     # (G,T,X)
    top_p, top_i = jax.lax.top_k(probs, mo.top_k)               # (G,T,K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)      # renormalize

    nx = mo.num_experts
    cap = _capacity(mo, tg)
    onehot = jax.nn.one_hot(top_i, nx, dtype=jnp.float32)       # (G,T,K,X)
    # position of each (token, slot) within its expert's arrival order
    flat = onehot.reshape(g, tg * mo.top_k, nx)
    pos_flat = jnp.cumsum(flat, axis=1) - 1.0                   # (G,T*K,X)
    pos = jnp.take_along_axis(
        pos_flat.reshape(g, tg, mo.top_k, nx),
        top_i[..., None], axis=-1)[..., 0]                      # (G,T,K)
    keep = pos < cap
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap,
                            dtype=jnp.float32) * keep[..., None]

    # dispatch: (G,T,X,C); combine adds router weights
    dispatch = jnp.einsum("gtkx,gtkc->gtxc", onehot, pos_oh)
    combine = jnp.einsum("gtkx,gtkc,gtk->gtxc", onehot, pos_oh, top_p)

    xe = jnp.einsum("gtxc,gtd->gxcd", dispatch.astype(x.dtype), xt)
    hg = jnp.einsum("gxcd,xdf->gxcf", xe, p["wg"])
    hu = jnp.einsum("gxcd,xdf->gxcf", xe, p["wi"])
    a = jax.nn.gelu(hg) if act == "gelu" else jax.nn.silu(hg)
    ye = jnp.einsum("gxcf,xfd->gxcd", a * hu, p["wo"])
    out = jnp.einsum("gtxc,gxcd->gtd", combine.astype(x.dtype), ye)
    out = out.reshape(b, s, d)

    if mo.shared_experts:
        out = out + cm.mlp_apply(p["shared"], x, act)

    # switch-style load-balance loss: X * sum_x f_x * P_x
    f = jnp.mean(dispatch.sum(axis=-1), axis=(0, 1))            # fraction per X
    pr = jnp.mean(probs, axis=(0, 1))
    aux = nx * jnp.sum(f * pr)
    return out, aux
