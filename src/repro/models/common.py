"""Shared transformer building blocks (functional, explicit param pytrees).

Conventions:
  * params are nested dicts of jnp arrays; compute dtype bf16, norm scales and
    rotary tables f32, softmax/logits accumulation f32.
  * einsum dim names: B batch, S/T seq (q/kv), D model, H q-heads, K kv-heads,
    G q-heads-per-kv (H = K*G), E head_dim, F d_ff, V vocab.
  * attention is blockwise (flash-style running softmax via lax.scan over kv
    blocks nested in a scan over q blocks) so 32k+ prefill never materializes
    (S, S) score matrices.  The TPU production path swaps in the Pallas paged
    kernel for decode (repro.kernels.paged_attention); the jnp path here is
    what the dry-run lowers (identical FLOPs/collectives, XLA-native HLO).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

DTYPE = jnp.bfloat16


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------

def ninit(key, shape, scale, dtype=DTYPE):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def zeros(shape, dtype=DTYPE):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-6):
    h = x.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    return (h * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings (RoPE and qwen2-vl M-RoPE)
# --------------------------------------------------------------------------

def rope_angles(positions, head_dim, theta, mrope_sections=None):
    """positions: (B, S) int32, or (3, B, S) for M-RoPE.
    Returns (cos, sin): (B, S, head_dim/2) f32."""
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if mrope_sections is None:
        pos = positions.astype(jnp.float32)            # (B, S)
        ang = pos[..., None] * inv_freq                # (B, S, half)
    else:
        assert positions.ndim == 3, "M-RoPE needs (3, B, S) positions"
        t, h, w = mrope_sections
        assert t + h + w == half, (mrope_sections, half)
        sec = jnp.concatenate([
            jnp.zeros((t,), jnp.int32),
            jnp.ones((h,), jnp.int32),
            jnp.full((w,), 2, jnp.int32),
        ])                                             # (half,) in {0,1,2}
        pos = positions.astype(jnp.float32)            # (3, B, S)
        pos_c = jnp.take(pos, sec, axis=0)             # (half, B, S)
        ang = jnp.moveaxis(pos_c, 0, -1) * inv_freq    # (B, S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, N, E); cos/sin: (B, S, E/2).  Rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :].astype(jnp.float32)
    s = sin[:, :, None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * c - x2f * s, x2f * c + x1f * s], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# blockwise (flash-style) attention
# --------------------------------------------------------------------------

NEG_INF = -1e30


def blockwise_attention(q, k, v, *, causal=True, window=None,
                        q_offset=0, q_block=512, kv_block=1024,
                        unroll=False):
    """q: (B, S, K, G, E); k, v: (B, T, K, E).  Returns (B, S, K, G, E).

    Running-softmax over kv blocks nested in a scan over q blocks; scores are
    (B, K, G, q_block, kv_block) f32 tiles only.  ``q_offset`` positions the
    query block absolutely (prefill continuation / decode windows).

    ``unroll=True`` replaces both scans with Python loops — identical math,
    used by the dry-run accounting pass because XLA's cost analysis counts
    while-loop bodies exactly once (see launch/accounting.py).
    """
    b, s, kh, g, e = q.shape
    t = k.shape[1]
    ve = v.shape[-1]  # value head dim may differ (MLA)
    assert k.shape[-1] == e, (k.shape, e)
    q_block = min(q_block, s)
    kv_block = min(kv_block, t)
    assert s % q_block == 0 and t % kv_block == 0, (s, q_block, t, kv_block)
    nq, nkv = s // q_block, t // kv_block
    scale = e ** -0.5

    qb = q.reshape(b, nq, q_block, kh, g, e)
    kb = k.reshape(b, nkv, kv_block, kh, e)
    vb = v.reshape(b, nkv, kv_block, kh, ve)

    q_pos_base = jnp.arange(q_block) + q_offset
    kv_pos_base = jnp.arange(kv_block)

    def outer(_, qi):
        qblk, qidx = qi                      # (B, q_block, K, G, E), scalar
        qpos = q_pos_base + qidx * q_block   # (q_block,)

        def inner(carry, kvi):
            m, norm, acc = carry
            kblk, vblk, kvidx = kvi
            kvpos = kv_pos_base + kvidx * kv_block
            srel = jnp.einsum("bqkge,btke->bkgqt", qblk, kblk,
                              preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((q_block, kv_block), bool)
            if causal:
                mask &= qpos[:, None] >= kvpos[None, :]
            if window is not None:
                mask &= (qpos[:, None] - kvpos[None, :]) < window
            srel = jnp.where(mask[None, None, None], srel, NEG_INF)
            m_new = jnp.maximum(m, srel.max(axis=-1))
            p = jnp.exp(srel - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            norm_new = norm * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bkgqt,btke->bkgqe", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * alpha[..., None] + pv
            return (m_new, norm_new, acc_new), None

        m0 = jnp.full((b, kh, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, kh, g, q_block, ve), jnp.float32)
        if unroll:
            carry = (m0, l0, a0)
            for j in range(nkv):
                carry, _ = inner(carry, (kb[:, j], vb[:, j], j))
            m, norm, acc = carry
        else:
            (m, norm, acc), _ = jax.lax.scan(
                inner, (m0, l0, a0),
                (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0),
                 jnp.arange(nkv)))
        out = acc / jnp.maximum(norm[..., None], 1e-30)
        return None, jnp.moveaxis(out, 3, 1).astype(q.dtype)  # (B,q_block,K,G,E)

    if unroll:
        outs = jnp.stack([outer(None, (qb[:, i], i))[1] for i in range(nq)])
    else:
        _, outs = jax.lax.scan(outer, None,
                               (jnp.moveaxis(qb, 1, 0), jnp.arange(nq)))
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, kh, g, ve)


def decode_attention(q, k_cache, v_cache, lengths, *, window=None):
    """Single-token attention over a (possibly seq-sharded) cache.

    q: (B, K, G, E); caches: (B, T, K, E); lengths: (B,) tokens valid
    (the new token's kv must already be written at lengths-1).
    Softmax reductions over the sharded T axis lower to all-reduces under
    GSPMD — the distributed-decode combine described in DESIGN.md §5.
    """
    b, t, kh, e = k_cache.shape
    scale = e ** -0.5
    s = jnp.einsum("bkge,btke->bkgt", q, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(t)[None, :]                        # (1, T)
    mask = pos < lengths[:, None]
    if window is not None:
        mask &= pos >= (lengths[:, None] - window)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    norm = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgt,btke->bkge", (p / norm).astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# GQA attention block
# --------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig):
    d, h, k, e = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": ninit(ks[0], (d, h, e), d ** -0.5),
        "wk": ninit(ks[1], (d, k, e), d ** -0.5),
        "wv": ninit(ks[2], (d, k, e), d ** -0.5),
        "wo": ninit(ks[3], (h, e, d), (h * e) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros((h, e))
        p["bk"] = zeros((k, e))
        p["bv"] = zeros((k, e))
    if cfg.qk_norm:
        p["q_norm"] = ones((e,))
        p["k_norm"] = ones((e,))
    return p


def attn_qkv(p, x, cfg: ModelConfig, cos, sin):
    """Project + position-encode.  x: (B,S,D) -> q (B,S,K,G,E), k/v (B,S,K,E)."""
    h, k = cfg.num_heads, cfg.num_kv_heads
    g = h // k
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    kx = jnp.einsum("bsd,dke->bske", x, p["wk"])
    vx = jnp.einsum("bsd,dke->bske", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        kx = kx + p["bk"]
        vx = vx + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        kx = rmsnorm(kx, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, cos, sin)
    kx = apply_rope(kx, cos, sin)
    b, s = q.shape[:2]
    return q.reshape(b, s, k, g, cfg.head_dim), kx, vx


def attn_out(p, o):
    """o: (B, S, K, G, E) -> (B, S, D)."""
    b, s, k, g, e = o.shape
    return jnp.einsum("bshe,hed->bsd", o.reshape(b, s, k * g, e), p["wo"])


# --------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# --------------------------------------------------------------------------

def mlp_init(key, d_model, d_ff):
    ks = jax.random.split(key, 3)
    return {
        "wi": ninit(ks[0], (d_model, d_ff), d_model ** -0.5),
        "wg": ninit(ks[1], (d_model, d_ff), d_model ** -0.5),
        "wo": ninit(ks[2], (d_ff, d_model), d_ff ** -0.5),
    }


def mlp_apply(p, x, act: str):
    gate = jnp.einsum("bsd,df->bsf", x, p["wg"])
    up = jnp.einsum("bsd,df->bsf", x, p["wi"])
    a = jax.nn.gelu(gate) if act == "gelu" else jax.nn.silu(gate)
    return jnp.einsum("bsf,fd->bsd", a * up, p["wo"])


# --------------------------------------------------------------------------
# embeddings / unembedding
# --------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig):
    v = cfg.vocab_padded()
    ks = jax.random.split(key, 2)
    p = {"table": ninit(ks[0], (v, cfg.d_model), cfg.d_model ** -0.5)}
    if not cfg.tie_embeddings:
        p["unembed"] = ninit(ks[1], (cfg.d_model, v), cfg.d_model ** -0.5)
    return p


def embed_apply(p, tokens, cfg: ModelConfig, one_hot_matmul: bool = False):
    if one_hot_matmul:
        # Vocab-parallel gather (§Perf): with the table sharded on vocab over
        # "model", jnp.take makes GSPMD all-gather the whole table; the
        # one-hot contraction keeps the table sharded and all-reduces only
        # the (B,S,D) result.
        oh = jax.nn.one_hot(tokens, p["table"].shape[0], dtype=p["table"].dtype)
        x = jnp.einsum("bsv,vd->bsd", oh, p["table"])
    else:
        x = jnp.take(p["table"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)  # gemma scaling
    return x


def unembed_apply(p, x, cfg: ModelConfig, shard=None):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["table"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["unembed"])
    if shard is not None:
        # keep logits vocab-sharded (Megatron vocab-parallel head) so the
        # weight is never gathered; the loss reduces over the shards
        logits = shard(logits, "logits")
    return logits
