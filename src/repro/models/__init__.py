"""LM substrate: composable JAX model definitions for the assigned archs."""
