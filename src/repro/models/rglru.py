"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Recurrent residual block: x -> (GeLU gate branch) ⊙ (conv1d -> RG-LRU branch)
-> output projection.  The RG-LRU recurrence

    r_t = sigmoid(W_a x_t),  i_t = sigmoid(W_x x_t)
    a_t = exp(c * r_t * -softplus(Λ))          (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

is linear in h, so the sequence form runs as a single
``jax.lax.associative_scan`` over (a, b) pairs — O(log S) depth, the
TPU-friendly formulation of the paper's hardware-aware linear recurrence.
Gate projections are block-diagonal (num_heads blocks), as in the
recurrentgemma reference code.  Decode keeps (h, conv window) as state —
constant-size, which is what makes the hybrid family `long_500k`-capable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm

C_FACTOR = 8.0


def rglru_init(key, cfg: ModelConfig):
    hy = cfg.hybrid
    d = cfg.d_model
    dr = hy.d_rnn or d
    nb = cfg.num_heads            # block-diagonal gate blocks
    bd = dr // nb
    ks = jax.random.split(key, 8)
    return {
        "w_gate": cm.ninit(ks[0], (d, dr), d ** -0.5),     # GeLU branch
        "w_x": cm.ninit(ks[1], (d, dr), d ** -0.5),        # recurrent branch
        "conv_w": cm.ninit(ks[2], (hy.conv_width, dr), hy.conv_width ** -0.5),
        "conv_b": cm.zeros((dr,)),
        "wa_gate": cm.ninit(ks[3], (nb, bd, bd), bd ** -0.5),
        "ba_gate": cm.zeros((dr,), jnp.float32),
        "wx_gate": cm.ninit(ks[4], (nb, bd, bd), bd ** -0.5),
        "bx_gate": cm.zeros((dr,), jnp.float32),
        # Λ init so that a^c spans ~(0.9, 0.999) as in the Griffin paper
        "lam": (jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, dr)) / C_FACTOR))
            ).astype(jnp.float32),
        "w_out": cm.ninit(ks[5], (dr, d), dr ** -0.5),
    }


def _block_linear(w, b, x):
    """Block-diagonal linear: x (B,S,NB,BD) @ w (NB,BD,BD)."""
    y = jnp.einsum("bsnd,nde->bsne", x, w)
    return y + b.reshape(1, 1, w.shape[0], -1).astype(y.dtype)


def _causal_conv(x, w, b, state=None):
    """Width-W causal conv over seq.  x: (B,S,D); state: (B,W-1,D) history.
    Returns (y, new_state)."""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i: i + x.shape[1]] * w[width - 1 - i] for i in range(width))
    return y + b, xp[:, -(width - 1):]


def _gates(p, xr, cfg):
    nb = cfg.num_heads
    b, s, dr = xr.shape
    xb = xr.reshape(b, s, nb, dr // nb)
    r = jax.nn.sigmoid(_block_linear(p["wa_gate"], p["ba_gate"], xb)
                       ).reshape(b, s, dr).astype(jnp.float32)
    i = jax.nn.sigmoid(_block_linear(p["wx_gate"], p["bx_gate"], xb)
                       ).reshape(b, s, dr).astype(jnp.float32)
    log_a = -C_FACTOR * r * jax.nn.softplus(p["lam"])          # (B,S,Dr) f32
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i \
        * xr.astype(jnp.float32)
    return a, gated_x


def rglru_seq(p, x, cfg: ModelConfig, conv_state=None, h0=None):
    """Full-sequence recurrent block.  x: (B,S,D) -> (y, (h_last, conv_state))."""
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_gate"]))
    xr, conv_state = _causal_conv(
        jnp.einsum("bsd,de->bse", x, p["w_x"]), p["conv_w"], p["conv_b"],
        conv_state)
    a, bterm = _gates(p, xr, cfg)
    if h0 is not None:
        # fold carried state into the first step: b_0 += a_0 * h0
        bterm = bterm.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, bterm), axis=1)
    y = (h.astype(x.dtype) * gate)
    return jnp.einsum("bse,ed->bsd", y, p["w_out"]), (h[:, -1], conv_state)


def rglru_step(p, x, cfg: ModelConfig, state):
    """Single-token decode.  x: (B,1,D); state = (h (B,Dr) f32, conv (B,W-1,Dr))."""
    h_prev, conv_state = state
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_gate"]))
    xr, conv_state = _causal_conv(
        jnp.einsum("bsd,de->bse", x, p["w_x"]), p["conv_w"], p["conv_b"],
        conv_state)
    a, bterm = _gates(p, xr, cfg)
    h = a[:, 0] * h_prev + bterm[:, 0]                         # (B,Dr)
    y = (h[:, None].astype(x.dtype) * gate)
    return jnp.einsum("bse,ed->bsd", y, p["w_out"]), (h, conv_state)
