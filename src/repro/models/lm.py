"""Unified causal-LM wrapper over the assigned architecture families.

One code path per *block kind*; an architecture is a list of homogeneous
segments, each executed as a ``lax.scan`` over stacked per-layer params (remat
applied to the scan body) so lowering stays compact even for 80-layer models:

  dense / vlm        [("blocks", ("dense",), L)]
  moe (mixtral)      [("blocks", ("moe",), L)]           + SWA window
  moe+mla (deepseek) [("d0", ("mla_dense",), 1), ("blocks", ("mla_moe",), L-1)]
  hybrid (griffin)   [("sb", ("rec","rec","attn_local"), L//3), ("tail", ("rec","rec"), 1)]
  ssm (mamba2)       [("blocks", ("ssd",), L)]
  audio (enc-dec)    encoder [("enc", ("enc",), Le)] + decoder [("dec", ("dec",), L)]

Phases: ``train`` (full seq, loss), ``prefill`` (full seq -> cache),
``decode`` (one token against the cache).  Caches are stacked along each
segment's scan dim.  ``shard`` is a callback hook through which the launch
layer injects ``with_sharding_constraint`` (identity on CPU tests).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rg_mod
from repro.models import ssd as ssd_mod

Shard = Callable[[jax.Array, str], jax.Array]


def _identity(x, name):
    return x


@dataclasses.dataclass(frozen=True)
class Segment:
    name: str
    kinds: tuple[str, ...]
    count: int


def segments_for(cfg: ModelConfig) -> list[Segment]:
    nl = cfg.num_layers
    if cfg.family == "ssm":
        return [Segment("blocks", ("ssd",), nl)]
    if cfg.family == "hybrid":
        pat = cfg.hybrid.pattern
        full, rem = divmod(nl, len(pat))
        segs = [Segment("sb", tuple(k if k != "attn" else "attn_local"
                                    for k in pat), full)]
        if rem:
            segs.append(Segment("tail", tuple(
                k if k != "attn" else "attn_local" for k in pat[:rem]), 1))
        return segs
    if cfg.family == "audio":
        return [Segment("dec", ("dec",), nl)]
    if cfg.moe is not None:
        if cfg.mla is not None:
            fd = cfg.moe.first_dense_layers
            segs = []
            if fd:
                segs.append(Segment("dense0", ("mla_dense",), fd))
            segs.append(Segment("blocks", ("mla_moe",), nl - fd))
            return segs
        return [Segment("blocks", ("moe",), nl)]
    return [Segment("blocks", ("dense",), nl)]


# --------------------------------------------------------------------------
# block init / apply, by kind
# --------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, kind: str):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": cm.ones((d,))}
    if kind in ("dense", "moe", "attn_local", "enc", "dec"):
        p["attn"] = cm.attn_init(ks[0], cfg)
    if kind in ("mla_dense", "mla_moe"):
        p["attn"] = mla_mod.mla_init(ks[0], cfg)
    if kind == "rec":
        p["rec"] = rg_mod.rglru_init(ks[0], cfg)
    if kind == "ssd":
        p["ssd"] = ssd_mod.ssd_init(ks[0], cfg)
        return p  # the mamba block is the whole layer
    if kind == "dec":
        p["ln_cross"] = cm.ones((d,))
        p["cross"] = cm.attn_init(ks[3], cfg)
    p["ln2"] = cm.ones((d,))
    if kind in ("moe", "mla_moe"):
        p["ffn"] = moe_mod.moe_init(ks[1], cfg)
    else:
        p["ffn"] = cm.mlp_init(ks[1], d, cfg.d_ff)
    return p


@dataclasses.dataclass
class Ctx:
    cfg: ModelConfig
    cos: jax.Array                       # (B, S, E/2)
    sin: jax.Array
    phase: str                           # train | prefill | decode
    shard: Shard = _identity
    lengths: Optional[jax.Array] = None  # (B,) decode: tokens valid incl. new
    cache_len: int = 0
    enc_out: Optional[jax.Array] = None  # audio: encoder output (B,Se,D)
    enc_cos: Optional[jax.Array] = None
    enc_sin: Optional[jax.Array] = None
    unroll: bool = False                 # accounting mode: no lax.scan loops
    attn_blocks: Optional[tuple] = None  # (q_block, kv_block) override
    uniform_pos: Optional[jax.Array] = None  # scalar decode position (§Perf)


def _prefill_cache_layout(arr, cache_len: int):
    """Lay a full-sequence (B, S, ...) tensor into a (B, cache_len, ...) ring
    buffer so that token t lands at slot t % cache_len (matching decode's
    ring write).  cache_len >= S pads with zeros."""
    s = arr.shape[1]
    if cache_len >= s:
        pad = [(0, 0)] * arr.ndim
        pad[1] = (0, cache_len - s)
        return jnp.pad(arr, pad)
    t0 = s - cache_len
    return jnp.roll(arr[:, -cache_len:], t0 % cache_len, axis=1)


def _ring_write(buf, new, lengths, shard: Shard, uniform_pos=None):
    """Write the new token's row at slot (lengths-1) % ring_len.

    With ``uniform_pos`` (all requests at the same position — the dry-run
    decode shapes and aligned serving buckets) the write is a single
    dynamic_update_slice, which XLA executes (and costs) in place; the
    general per-request path is a batched scatter that reads+writes the
    whole buffer on some backends (§Perf iteration 1)."""
    ring = buf.shape[1]
    if uniform_pos is not None:
        idx = (uniform_pos - 0) % ring  # uniform_pos is the new token's slot
        upd = new[:, :1] if new.ndim == buf.ndim else new[:, None]
        start = (0, idx) + (0,) * (buf.ndim - 2)
        return shard(jax.lax.dynamic_update_slice(buf, upd.astype(buf.dtype),
                                                  start), "cache_kv")
    idx = (lengths - 1) % ring
    bidx = jnp.arange(new.shape[0])
    return shard(buf.at[bidx, idx].set(new[:, 0]), "cache_kv")


def _attn_sublayer(p, x, ctx: Ctx, cache, *, window, causal=True):
    cfg = ctx.cfg
    h = cm.rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = cm.attn_qkv(p["attn"], h, cfg, ctx.cos, ctx.sin)
    if ctx.phase == "decode":
        cache = {"k": _ring_write(cache["k"], k, ctx.lengths, ctx.shard,
                                  ctx.uniform_pos),
                 "v": _ring_write(cache["v"], v, ctx.lengths, ctx.shard,
                                  ctx.uniform_pos)}
        cl = cache["k"].shape[1]
        valid = jnp.minimum(ctx.lengths, cl)
        win = None if (window is None or window >= cl) else window
        o = cm.decode_attention(q[:, 0], cache["k"], cache["v"], valid,
                                window=win)[:, None]
    else:
        kw = {}
        if ctx.attn_blocks:
            kw = {"q_block": ctx.attn_blocks[0], "kv_block": ctx.attn_blocks[1]}
        # TP-friendly layout: expand kv-heads to full H so the head dim (the
        # "model"-sharded one) is a single contiguous axis.  k/v are
        # replicated across model shards; the expansion lowers to a local
        # broadcast slice, never a collective (DESIGN.md §5).  The sequence
        # all-gather (SP) is pinned to the COMPACT (B,S,K,E) form first —
        # see Rules.act_shard("kv_compact") and EXPERIMENTS.md §Perf.
        b_, s_, kh_, g_, e_ = q.shape
        k = ctx.shard(k, "kv_compact")
        v = ctx.shard(v, "kv_compact")
        qf = ctx.shard(q.reshape(b_, s_, kh_ * g_, 1, e_), "q_heads")
        kf = ctx.shard(jnp.repeat(k, g_, axis=2), "kv_heads")
        vf = ctx.shard(jnp.repeat(v, g_, axis=2), "kv_heads")
        o = cm.blockwise_attention(qf, kf, vf, causal=causal, window=window,
                                   unroll=ctx.unroll, **kw)
        o = o.reshape(b_, s_, kh_, g_, e_)
        if ctx.phase == "prefill":
            cl = ctx.cache_len if window is None else min(ctx.cache_len, window)
            cache = {"k": _prefill_cache_layout(k, cl),
                     "v": _prefill_cache_layout(v, cl)}
    x = x + cm.attn_out(p["attn"], o)
    return x, cache


def _cross_sublayer(p, x, ctx: Ctx, cache):
    """Encoder-decoder cross attention; kv comes from enc_out (cached)."""
    cfg = ctx.cfg
    h = cm.rmsnorm(x, p["ln_cross"], cfg.norm_eps)
    hq = cfg.num_heads // cfg.num_kv_heads
    if ctx.phase == "decode":
        ck, cv = cache["ck"], cache["cv"]
    else:
        # zero-position rope on cross kv (relative positions are meaningless
        # across modalities; standard practice is no rope on cross-attn)
        ck = jnp.einsum("bsd,dke->bske", ctx.enc_out, p["cross"]["wk"])
        cv = jnp.einsum("bsd,dke->bske", ctx.enc_out, p["cross"]["wv"])
        if ctx.phase == "prefill":
            cache = {"ck": ck, "cv": cv}
    q = jnp.einsum("bsd,dhe->bshe", h, p["cross"]["wq"])
    b, s = q.shape[:2]
    q = q.reshape(b, s, cfg.num_kv_heads, hq, cfg.head_dim)
    if ctx.phase == "decode":
        lengths = jnp.full((b,), ck.shape[1], jnp.int32)
        o = cm.decode_attention(q[:, 0], ck, cv, lengths)[:, None]
    else:
        o = cm.blockwise_attention(q, ck, cv, causal=False)
    x = x + cm.attn_out(p["cross"], o)
    return x, cache


def _ffn_sublayer(p, x, ctx: Ctx):
    cfg = ctx.cfg
    h = cm.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe is not None and isinstance(p["ffn"], dict) \
            and "router" in p["ffn"]:
        out, aux = moe_mod.moe_apply(p["ffn"], h, cfg, cfg.act)
        return x + out, aux
    return x + cm.mlp_apply(p["ffn"], h, cfg.act), 0.0


def _mla_sublayer(p, x, ctx: Ctx, cache):
    cfg = ctx.cfg
    h = cm.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if ctx.phase == "decode":
        c_kv_new, k_rope_new = mla_mod.mla_latent(p["attn"], h, cfg,
                                                  ctx.cos, ctx.sin)
        cache = {
            "ckv": _ring_write(cache["ckv"], c_kv_new, ctx.lengths, ctx.shard,
                               ctx.uniform_pos),
            "krope": _ring_write(cache["krope"], k_rope_new[:, :, 0],
                                 ctx.lengths, ctx.shard, ctx.uniform_pos),
        }
        valid = jnp.minimum(ctx.lengths, cache["ckv"].shape[1])
        o = mla_mod.mla_decode(p["attn"], h, cfg, ctx.cos, ctx.sin,
                               (cache["ckv"], cache["krope"]), valid)
        return x + o, cache
    kw = {}
    if ctx.attn_blocks:
        kw = {"q_block": ctx.attn_blocks[0], "kv_block": ctx.attn_blocks[1]}
    o, (c_kv, k_rope) = mla_mod.mla_attention(p["attn"], h, cfg,
                                              ctx.cos, ctx.sin,
                                              unroll=ctx.unroll,
                                              shard=ctx.shard, **kw)
    if ctx.phase == "prefill":
        cache = {"ckv": _prefill_cache_layout(c_kv, ctx.cache_len),
                 "krope": _prefill_cache_layout(k_rope, ctx.cache_len)}
    return x + o, cache


def _state_sublayer(kind, p, x, ctx: Ctx, cache):
    key = "rec" if kind == "rec" else "ssd"
    if ctx.phase == "decode":
        step = rg_mod.rglru_step if kind == "rec" else ssd_mod.ssd_step
        o, (h, conv) = step(p[key], x, ctx.cfg, (cache["h"], cache["conv"]))
        return o, {"h": h, "conv": conv}
    if kind == "rec":
        o, (h, conv) = rg_mod.rglru_seq(p[key], x, ctx.cfg)
    else:
        o, (h, conv) = ssd_mod.ssd_seq(p[key], x, ctx.cfg, unroll=ctx.unroll)
    cache = {"h": h, "conv": conv} if ctx.phase == "prefill" else None
    return o, cache


def block_apply(kind: str, p, x, ctx: Ctx, cache):
    """Apply one block.  Returns (x, cache, aux)."""
    cfg = ctx.cfg
    aux = 0.0
    if kind in ("dense", "moe", "enc"):
        x, cache = _attn_sublayer(p, x, ctx, cache, window=cfg.window,
                                  causal=(kind != "enc"))
        x, aux = _ffn_sublayer(p, x, ctx)
    elif kind == "attn_local":
        x, cache = _attn_sublayer(p, x, ctx, cache,
                                  window=cfg.hybrid.local_window)
        x, aux = _ffn_sublayer(p, x, ctx)
    elif kind in ("mla_dense", "mla_moe"):
        x, cache = _mla_sublayer(p, x, ctx, cache)
        x, aux = _ffn_sublayer(p, x, ctx)
    elif kind == "dec":
        x, self_cache = _attn_sublayer(p, x, ctx,
                                       None if cache is None else cache.get("self"),
                                       window=None)
        x, cross_cache = _cross_sublayer(p, x, ctx,
                                         None if cache is None else cache.get("cross"))
        x, aux = _ffn_sublayer(p, x, ctx)
        cache = None if self_cache is None and cross_cache is None else \
            {"self": self_cache, "cross": cross_cache}
    elif kind in ("rec", "ssd"):
        o, cache = _state_sublayer(kind, p, x, ctx, cache)
        x = x + o
        if kind == "rec":  # griffin rec blocks also carry an MLP residual
            x, aux = _ffn_sublayer(p, x, ctx)
    else:
        raise ValueError(kind)
    x = ctx.shard(x, "act")
    return x, cache, aux


# --------------------------------------------------------------------------
# the model
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LM:
    cfg: ModelConfig
    remat_policy: str = "minimal"   # minimal | dots | off
    unroll: bool = False            # accounting mode (launch/accounting.py)
    attn_blocks: Optional[tuple] = None  # (q_block, kv_block) override
    decode_carry_cache: bool = False  # §Perf: in-place cache via loop carry
    assume_uniform_decode: bool = False  # §Perf: all requests share position
    vocab_parallel: bool = False    # §Perf: one-hot embed + sharded logits

    def _ctx(self, **kw) -> Ctx:
        return Ctx(cfg=self.cfg, unroll=self.unroll,
                   attn_blocks=self.attn_blocks, **kw)

    # -- params ------------------------------------------------------------
    def init_params(self, key) -> dict:
        cfg = self.cfg
        segs = segments_for(cfg)
        keys = jax.random.split(key, len(segs) + 2)
        params: dict[str, Any] = {
            "embed": cm.embed_init(keys[0], cfg),
            "final_norm": cm.ones((cfg.d_model,)),
        }
        for seg, k in zip(segs, keys[1:]):
            def init_one(lk):
                sks = jax.random.split(lk, len(seg.kinds))
                return {f"sub{i}": _block_init(sk, cfg, kind)
                        for i, (kind, sk) in enumerate(zip(seg.kinds, sks))}
            params[seg.name] = jax.vmap(init_one)(
                jax.random.split(k, seg.count))
        if cfg.enc_layers:
            def init_enc(lk):
                return {"sub0": _block_init(lk, cfg, "enc")}
            params["enc"] = jax.vmap(init_enc)(
                jax.random.split(keys[-1], cfg.enc_layers))
            params["enc_norm"] = cm.ones((cfg.d_model,))
        return params

    # -- segment scan machinery ---------------------------------------------
    def _remat(self, fn):
        if self.remat_policy == "off":
            return fn
        if self.remat_policy == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.checkpoint_dots)
        return jax.checkpoint(fn)

    def _run_segment(self, seg: Segment, seg_params, x, ctx: Ctx,
                     cache=None):
        """Scan over a segment's layers.  Returns (x, new_cache, aux_sum)."""

        def body(carry, xs):
            x, aux = carry
            p_layer, c_layer = xs
            new_c = {}
            for i, kind in enumerate(seg.kinds):
                ci = None if c_layer is None else c_layer[f"sub{i}"]
                x, ci, a = block_apply(kind, p_layer[f"sub{i}"], x, ctx, ci)
                new_c[f"sub{i}"] = ci
                aux = aux + a
            if all(v is None for v in new_c.values()):
                new_c = None
            return (x, aux), new_c

        body = self._remat(body) if ctx.phase == "train" else body

        if ctx.phase == "decode" and self.decode_carry_cache:
            # §Perf iteration: the default scan emits the new cache as
            # stacked ys — a full cache copy per step.  Carrying the cache
            # through the loop and updating each layer's slice in place
            # (dynamic_update_slice on the carried buffer, which XLA aliases
            # across iterations) removes the copy.
            if self.unroll:
                aux = jnp.zeros((), jnp.float32)
                new_cache = cache
                for i in range(seg.count):
                    p_i = jax.tree.map(lambda a: a[i], seg_params)
                    c_i = jax.tree.map(lambda a: a[i], new_cache)
                    (x, aux), c_new = body((x, aux), (p_i, c_i))
                    new_cache = jax.tree.map(
                        lambda full, upd, i=i: full.at[i].set(upd),
                        new_cache, c_new)
                return x, new_cache, aux

            def floop(i, carry):
                x, cch, aux = carry
                p_i = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        a, i, keepdims=False), seg_params)
                c_i = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        a, i, keepdims=False), cch)
                (x, aux), c_new = body((x, aux), (p_i, c_i))
                cch = jax.tree.map(
                    lambda full, upd: jax.lax.dynamic_update_index_in_dim(
                        full, upd.astype(full.dtype), i, 0), cch, c_new)
                return (x, cch, aux)

            x, caches, aux = jax.lax.fori_loop(
                0, seg.count, floop,
                (x, cache, jnp.zeros((), jnp.float32)))
            return x, caches, aux

        if self.unroll:
            # accounting mode: Python loop so XLA cost analysis sees every
            # layer's ops (while-loop bodies are otherwise counted once)
            carry = (x, jnp.zeros((), jnp.float32))
            cache_out = []
            for i in range(seg.count):
                xs_i = jax.tree.map(lambda a: a[i], (seg_params, cache))
                carry, c_i = body(carry, xs_i)
                cache_out.append(c_i)
            (x, aux) = carry
            caches = None if cache_out[0] is None else jax.tree.map(
                lambda *ls: jnp.stack(ls), *cache_out)
            return x, caches, aux
        (x, aux), caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (seg_params, cache))
        return x, caches, aux

    # -- positions / rope ----------------------------------------------------
    def _angles(self, positions):
        cfg = self.cfg
        e = cfg.head_dim
        if cfg.mla is not None:
            e = cfg.mla.rope_head_dim
        return cm.rope_angles(positions, e, cfg.rope_theta,
                              cfg.mrope_sections)

    def _decode_positions(self, positions):
        # positions: (B,) index of the new token
        if self.cfg.mrope_sections is not None:
            return jnp.broadcast_to(positions[None, :, None],
                                    (3,) + positions.shape + (1,))
        return positions[:, None]

    # -- encoder (audio) -----------------------------------------------------
    def _encode(self, params, frames, ctx_shard: Shard):
        cfg = self.cfg
        b, s, _ = frames.shape
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        cos, sin = self._angles(pos)
        ctx = self._ctx(cos=cos, sin=sin, phase="train", shard=ctx_shard)
        seg = Segment("enc", ("enc",), cfg.enc_layers)
        x, _, _ = self._run_segment(seg, params["enc"], frames, ctx)
        return cm.rmsnorm(x, params["enc_norm"], cfg.norm_eps)

    # -- train forward -------------------------------------------------------
    def forward_train(self, params, batch, shard: Shard = _identity):
        """batch: dict with tokens (B,S) int32, labels (B,S) int32 (-1 = pad),
        optional positions, vision_embeds, enc_frames."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = batch.get("positions")
        if positions is None:
            pos2d = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            positions = (jnp.broadcast_to(pos2d[None], (3, b, s))
                         if cfg.mrope_sections is not None else pos2d)
        cos, sin = self._angles(positions)

        x = cm.embed_apply(params["embed"], tokens, cfg,
                           one_hot_matmul=self.vocab_parallel)
        if cfg.family == "vlm" and "vision_embeds" in batch:
            ve = batch["vision_embeds"].astype(x.dtype)   # (B, NV, D) stub
            nv = ve.shape[1]
            x = jnp.concatenate([ve, x[:, nv:]], axis=1)
        x = shard(x, "act")

        enc_out = None
        if cfg.enc_layers:
            enc_out = self._encode(params, batch["enc_frames"].astype(x.dtype),
                                   shard)
        ctx = self._ctx(cos=cos, sin=sin, phase="train", shard=shard,
                        enc_out=enc_out)

        aux_total = jnp.zeros((), jnp.float32)
        for seg in segments_for(cfg):
            x, _, aux = self._run_segment(seg, params[seg.name], x, ctx)
            aux_total = aux_total + aux
        x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = cm.unembed_apply(params["embed"], x, cfg,
                                  shard=shard if self.vocab_parallel else None)
        return logits, aux_total

    def loss(self, params, batch, shard: Shard = _identity,
             aux_weight: float = 0.01):
        logits, aux = self.forward_train(params, batch, shard)
        labels = batch["labels"]
        mask = labels >= 0
        lab = jnp.maximum(labels, 0)
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        if self.vocab_parallel:
            # shard-local gold pick: reduces over the vocab-sharded axis
            # instead of gathering logits (Megatron vocab-parallel CE)
            vid = jnp.arange(logits.shape[-1])[None, None, :]
            gold = jnp.sum(jnp.where(vid == lab[..., None], logits, 0.0), -1)
        else:
            gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll = jnp.where(mask, lse - gold, 0.0)
        ntok = jnp.maximum(mask.sum(), 1)
        ce = nll.sum() / ntok
        return ce + aux_weight * aux, {"ce": ce, "aux": aux}

    # -- cache construction ---------------------------------------------------
    def cache_struct(self, batch: int, cache_len: int, enc_len: int = 0):
        """Abstract cache pytree (ShapeDtypeStructs) for serve_step lowering."""
        cfg = self.cfg
        dt = cm.DTYPE

        def sds(shape, dtype=dt):
            return jax.ShapeDtypeStruct(shape, dtype)

        def leaf(kind):
            k, e = cfg.num_kv_heads, cfg.head_dim
            if kind in ("dense", "moe", "enc", "attn_local"):
                cl = cache_len
                if kind == "attn_local":
                    cl = min(cache_len, cfg.hybrid.local_window)
                if kind == "moe" and cfg.window:
                    cl = min(cache_len, max(cfg.window, 1))
                return {"k": sds((batch, cl, k, e)), "v": sds((batch, cl, k, e))}
            if kind in ("mla_dense", "mla_moe"):
                m = cfg.mla
                return {"ckv": sds((batch, cache_len, m.kv_lora_rank)),
                        "krope": sds((batch, cache_len, m.rope_head_dim))}
            if kind == "dec":
                return {"self": {"k": sds((batch, cache_len, k, e)),
                                 "v": sds((batch, cache_len, k, e))},
                        "cross": {"ck": sds((batch, enc_len, k, e)),
                                  "cv": sds((batch, enc_len, k, e))}}
            if kind == "rec":
                dr = cfg.hybrid.d_rnn or cfg.d_model
                return {"h": sds((batch, dr), jnp.float32),
                        "conv": sds((batch, cfg.hybrid.conv_width - 1, dr))}
            if kind == "ssd":
                s = cfg.ssm
                d_in = s.expand * cfg.d_model
                nheads = d_in // s.head_dim
                gn = s.n_groups * s.d_state
                w = s.conv_width - 1
                return {"h": sds((batch, nheads, s.d_state, s.head_dim),
                                 jnp.float32),
                        "conv": {"x": sds((batch, w, d_in)),
                                 "b": sds((batch, w, gn)),
                                 "c": sds((batch, w, gn))}}
            raise ValueError(kind)

        def stack(tree, n):
            return jax.tree.map(
                lambda a: jax.ShapeDtypeStruct((n,) + a.shape, a.dtype), tree)

        return {seg.name: stack({f"sub{i}": leaf(k)
                                 for i, k in enumerate(seg.kinds)}, seg.count)
                for seg in segments_for(cfg)}

    def init_cache(self, batch: int, cache_len: int, enc_len: int = 0):
        structs = self.cache_struct(batch, cache_len, enc_len)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), structs)

    # -- decode ---------------------------------------------------------------
    def decode_step(self, params, cache, tokens, positions,
                    shard: Shard = _identity, cache_len: int = 0):
        """tokens: (B,) int32 new token ids; positions: (B,) their indices.
        Returns (logits (B, V), new_cache)."""
        cfg = self.cfg
        cache_len = cache_len or self._cache_len_from(cache)
        cos, sin = self._angles(self._decode_positions(positions))
        x = cm.embed_apply(params["embed"], tokens[:, None], cfg)
        ctx = self._ctx(cos=cos, sin=sin, phase="decode", shard=shard,
                        lengths=positions + 1, cache_len=cache_len)
        if self.assume_uniform_decode:
            ctx.uniform_pos = positions[0]
        new_cache = {}
        for seg in segments_for(cfg):
            x, new_cache[seg.name], _ = self._run_segment(
                seg, params[seg.name], x, ctx, cache[seg.name])
        x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = cm.unembed_apply(params["embed"], x, cfg)
        return logits[:, 0], new_cache

    def _cache_len_from(self, cache) -> int:
        for seg in segments_for(self.cfg):
            sub = cache[seg.name]["sub0"]
            for key in ("k", "ckv"):
                if key in sub:
                    return sub[key].shape[2]
            if "self" in sub:
                return sub["self"]["k"].shape[2]
        # state-space models: no kv length; ring length is irrelevant
        return 1

    # -- prefill ----------------------------------------------------------------
    def prefill(self, params, batch, cache_len: int,
                shard: Shard = _identity):
        """Full-sequence forward that also returns the populated cache and the
        last-token logits."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = batch.get("positions")
        if positions is None:
            pos2d = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            positions = (jnp.broadcast_to(pos2d[None], (3, b, s))
                         if cfg.mrope_sections is not None else pos2d)
        cos, sin = self._angles(positions)
        x = cm.embed_apply(params["embed"], tokens, cfg)
        if cfg.family == "vlm" and "vision_embeds" in batch:
            ve = batch["vision_embeds"].astype(x.dtype)
            x = jnp.concatenate([ve, x[:, ve.shape[1]:]], axis=1)
        x = shard(x, "act")
        enc_out = None
        if cfg.enc_layers:
            enc_out = self._encode(params, batch["enc_frames"].astype(x.dtype),
                                   shard)
        ctx = self._ctx(cos=cos, sin=sin, phase="prefill", shard=shard,
                        cache_len=cache_len, enc_out=enc_out)
        caches = {}
        for seg in segments_for(cfg):
            x, caches[seg.name], _ = self._run_segment(
                seg, params[seg.name], x, ctx)
        x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = cm.unembed_apply(params["embed"], x[:, -1:], cfg)
        return logits[:, 0], caches
