"""DeepSeek-V2 Multi-head Latent Attention (MLA).

Train/prefill: queries through a low-rank path (q_lora), keys/values through a
shared compressed latent c_kv (kv_lora_rank=512) plus a decoupled shared RoPE
key (rope_head_dim=64).  The *cache* stores only (c_kv, k_rope) per token —
576 numbers instead of 2*H*E = 32768 — which is why DESIGN.md calls MLA pages
the best-case parked payload.

Decode uses the absorbed formulation: W^UK is folded into the query and W^UV
into the output so attention runs directly against the latent cache —
per-token FLOPs O(H * kv_lora) instead of O(H * E * T) re-expansion.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm


def mla_init(key, cfg: ModelConfig):
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qd = m.nope_head_dim + m.rope_head_dim
    ks = jax.random.split(key, 8)
    return {
        "wq_a": cm.ninit(ks[0], (d, m.q_lora_rank), d ** -0.5),
        "q_norm": cm.ones((m.q_lora_rank,)),
        "wq_b": cm.ninit(ks[1], (m.q_lora_rank, h, qd), m.q_lora_rank ** -0.5),
        "wkv_a": cm.ninit(ks[2], (d, m.kv_lora_rank + m.rope_head_dim),
                          d ** -0.5),
        "kv_norm": cm.ones((m.kv_lora_rank,)),
        "wk_b": cm.ninit(ks[3], (m.kv_lora_rank, h, m.nope_head_dim),
                         m.kv_lora_rank ** -0.5),
        "wv_b": cm.ninit(ks[4], (m.kv_lora_rank, h, m.v_head_dim),
                         m.kv_lora_rank ** -0.5),
        "wo": cm.ninit(ks[5], (h, m.v_head_dim, d), (h * m.v_head_dim) ** -0.5),
    }


def mla_latent(p, x, cfg: ModelConfig, cos, sin):
    """Compress x to the cached latent: (c_kv (B,S,R), k_rope (B,S,1,Er))."""
    m = cfg.mla
    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv = cm.rmsnorm(kv_a[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = kv_a[..., m.kv_lora_rank:][:, :, None, :]  # (B,S,1,Er)
    k_rope = cm.apply_rope(k_rope, cos, sin)
    return c_kv, k_rope


def mla_queries(p, x, cfg: ModelConfig, cos, sin):
    """Return (q_nope (B,S,H,En), q_rope (B,S,H,Er))."""
    m = cfg.mla
    cq = cm.rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"],
                    cfg.norm_eps)
    q = jnp.einsum("bsr,rhe->bshe", cq, p["wq_b"])
    q_nope = q[..., : m.nope_head_dim]
    q_rope = cm.apply_rope(q[..., m.nope_head_dim:], cos, sin)
    return q_nope, q_rope


def mla_attention(p, x, cfg: ModelConfig, cos, sin, q_block=512,
                  kv_block=1024, unroll=False, shard=None):
    """Full-sequence MLA attention (train / prefill).  Returns (out, cache)
    where cache = (c_kv, k_rope) for the serving layer."""
    m = cfg.mla
    h = cfg.num_heads
    q_nope, q_rope = mla_queries(p, x, cfg, cos, sin)
    c_kv, k_rope = mla_latent(p, x, cfg, cos, sin)
    if shard is not None:
        # sequence-gather the 576-dim latent, not the 24k-dim expansion
        c_kv = shard(c_kv, "mla_latent")
        k_rope = shard(k_rope[:, :, 0], "mla_latent")[:, :, None]

    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, p["wk_b"])
    v = jnp.einsum("bsr,rhe->bshe", c_kv, p["wv_b"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)          # (B,S,H,En+Er)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:3] + (m.rope_head_dim,))],
        axis=-1)
    if shard is not None:
        k = shard(k, "kv_heads")
        v = shard(v, "kv_heads")

    b, s = x.shape[:2]
    q = q.reshape(b, s, h, 1, -1)
    if shard is not None:
        q = shard(q, "q_heads")
    o = cm.blockwise_attention(
        q, k, v, causal=True,
        q_block=q_block, kv_block=kv_block, unroll=unroll)   # (B,S,H,1,Ev)
    out = jnp.einsum("bshe,hed->bsd", o[:, :, :, 0], p["wo"])
    return out, (c_kv, k_rope[:, :, 0, :])


def mla_decode(p, x, cfg: ModelConfig, cos, sin, cache, lengths):
    """Absorbed single-token decode.  cache = (c_kv (B,T,R), k_rope (B,T,Er)),
    already updated with the current token at lengths-1."""
    m = cfg.mla
    q_nope, q_rope = mla_queries(p, x, cfg, cos, sin)       # (B,1,H,*)
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]             # (B,H,*)
    c_kv, k_rope = cache

    # absorb W^UK: q_lat (B,H,R)
    q_lat = jnp.einsum("bhe,rhe->bhr", q_nope, p["wk_b"])
    s_lat = jnp.einsum("bhr,btr->bht", q_lat, c_kv,
                       preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bhe,bte->bht", q_rope, k_rope,
                        preferred_element_type=jnp.float32)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    s = (s_lat + s_rope) * scale
    t = c_kv.shape[1]
    mask = jnp.arange(t)[None, :] < lengths[:, None]
    s = jnp.where(mask[:, None], s, cm.NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bht,btr->bhr", pattn.astype(c_kv.dtype), c_kv)
    o = jnp.einsum("bhr,rhe->bhe", o_lat, p["wv_b"])        # absorb W^UV
    return jnp.einsum("bhe,hed->bd", o, p["wo"])[:, None, :]
