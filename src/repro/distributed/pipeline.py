"""GPipe-style pipeline parallelism over a mesh axis (default: "pod").

Microbatches stream through stages placed on successive mesh-axis slices;
activations move stage-to-stage with ``jax.lax.ppermute`` inside a
``shard_map``.  The static schedule runs ``num_micro + S - 1`` ticks; each
tick every stage computes one microbatch and forwards it, so the ppermute
overlaps with the next tick's compute (XLA schedules the send/recv around the
stage body — the compute/communication overlap the brief asks for).

Offered as an optional distribution mode: the production dry-run meshes use
("pod","data","model") with pod folded into data parallelism by default;
``pipeline_apply`` reuses the pod axis as the stage axis instead (bubble
fraction (S-1)/(T+S-1)).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def sequential_apply(stage_fn, stage_params, x):
    """Reference: run every stage in order over each microbatch.
    stage_params: (S, ...); x: (num_micro, mb, d)."""
    s = stage_params.shape[0] if hasattr(stage_params, "shape") else \
        jax.tree.leaves(stage_params)[0].shape[0]

    def body(xm):
        for i in range(s):
            xm = stage_fn(jax.tree.map(lambda a: a[i], stage_params), xm)
        return xm

    return jax.vmap(body)(x)


def pipeline_apply(stage_fn, stage_params, x, mesh, stage_axis: str = "pod"):
    """x: (num_micro, mb, d) replicated; stage_params sharded over
    ``stage_axis`` (one stage per slice).  Returns (num_micro, mb, d)."""
    s = mesh.shape[stage_axis]
    num_micro = x.shape[0]
    nstages = jax.tree.leaves(stage_params)[0].shape[0]
    assert nstages == s, (nstages, s)
    perm = [(i, (i + 1) % s) for i in range(s)]

    pspec = jax.tree.map(lambda _: P(stage_axis), stage_params)
    xspec = P(*([None] * x.ndim))

    @partial(shard_map, mesh=mesh, in_specs=(pspec, xspec),
             out_specs=xspec, check_rep=False)
    def run(params_local, x_all):
        stage_id = jax.lax.axis_index(stage_axis)
        is_first = stage_id == 0
        is_last = stage_id == s - 1
        p_local = jax.tree.map(lambda a: a[0], params_local)
        state = jnp.zeros_like(x_all[0])
        outputs = jnp.zeros_like(x_all)
        for t in range(num_micro + s - 1):
            feed = x_all[min(t, num_micro - 1)]
            x_in = jnp.where(is_first & (t < num_micro), feed, state)
            y = stage_fn(p_local, x_in)
            mb = t - (s - 1)
            if mb >= 0:
                outputs = outputs.at[mb].set(
                    jnp.where(is_last, y, outputs[mb]))
            state = jax.lax.ppermute(y, stage_axis, perm)
        # only the last stage wrote outputs; broadcast over the stage axis
        return jax.lax.psum(outputs, stage_axis)

    return run(stage_params, x)
