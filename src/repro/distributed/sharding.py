"""Logical-axis sharding rules: param/batch/cache pytrees -> PartitionSpecs.

Megatron-style 2-D (+pod) layout on mesh axes ("pod", "data", "model"):
  * batch over ("pod", "data") — pod folds into data parallelism;
  * attention heads / FFN hidden / vocab over "model" (tensor parallel);
  * GQA kv-head projections shard over "model" only when kv_heads divides the
    axis; otherwise they replicate and the *decode KV cache* shards over the
    sequence axis instead (context parallelism) — the distributed-decode
    softmax combine lowers to all-reduces;
  * MoE experts shard over "model" when num_experts divides it (EP —
    deepseek's 160/16), else expert-internal d_ff shards (TP — mixtral's 8);
  * SSD heads and RG-LRU channels shard over "model" (head-parallel scan).

Rules are name+shape based over the flattened param paths; anything
unmatched replicates.  ``guarded(axis, dim)`` falls back to replication when
the dimension does not divide the axis size — so every rule is safe for the
reduced CPU smoke configs as well as the full 512-chip mesh.

Two consumers share the helpers here (``axis_size``/``divides_axis`` and
the guarded-fallback idiom):

  * ``launch/dryrun.py`` — the serving/training side: ``Rules`` resolves
    PartitionSpecs for every (arch x shape x mesh) dry-run cell;
  * ``switchsim/fabric.py`` — the dataplane side: the engine's flat pipe
    axis shard_mapped over a 1-D ``("switch",)`` mesh, replicating (one
    device) whenever the pipe count does not divide the device count
    (DESIGN.md §12).
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


def axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        return int(np.prod([axis_size(mesh, n) for n in name]))
    return mesh.shape[name] if name in mesh.shape else 1


def divides_axis(dim: int, size: int) -> bool:
    """The guarded-sharding predicate: can ``dim`` shard over an axis of
    ``size`` devices without padding?  Every sharding decision in this
    repo — ``Rules.g`` for model dims, ``fabric.resolve_devices`` for the
    pipe axis — routes through this one check so "doesn't divide" always
    means the same thing: fall back to replication, never pad or crash."""
    return dim % max(size, 1) == 0


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


class Rules:
    """Resolve PartitionSpecs for one (cfg, mesh) pair.

    ``fsdp=True`` additionally shards every >=2-D weight's first free
    divisible dim over "data" (ZeRO-3 within a pod; pods hold replicas and
    all-reduce grads over DCN).  With scan-over-layers the per-layer
    all-gather happens inside the loop — the standard FSDP+scan pattern.
    """

    def __init__(self, cfg: ModelConfig, mesh: Mesh,
                 seq_sharded_cache: bool = True,
                 sp_activations: bool = False,
                 fsdp: bool = True,
                 head_sharded_cache: bool = False,
                 pin_attn_heads: bool = False):
        self.cfg = cfg
        self.mesh = mesh
        self.model = axis_size(mesh, "model")
        self.dp = dp_axes(mesh)
        self.seq_sharded_cache = seq_sharded_cache
        self.sp_activations = sp_activations
        self.fsdp = fsdp
        # §Perf: shard the decode cache on kv-heads instead of sequence when
        # kv_heads divides the model axis — token writes become local DUS and
        # decode attention needs no cross-shard softmax combine.
        self.head_sharded_cache = head_sharded_cache
        # §Perf it3: pinning q/kv head sharding through attention reshapes
        # helps MLA (deepseek −13% collectives) but HURTS plain GQA
        # (qwen2-vl +72%) — hence opt-in, chosen per arch.
        self.pin_attn_heads = pin_attn_heads

    def _add_fsdp(self, spec: P, shape: tuple[int, ...]) -> P:
        if not self.fsdp or len(shape) < 2:
            return spec
        data = axis_size(self.mesh, "data")
        parts = list(spec) + [None] * (len(shape) - len(spec))
        for i, (p, dim) in enumerate(zip(parts, shape)):
            if p is None and dim % max(data, 1) == 0 and dim >= data:
                parts[i] = "data"
                break
        return P(*parts)

    # -- helpers ------------------------------------------------------------
    def g(self, dim: int, axis: str = "model") -> Optional[str]:
        """axis if dim divides its size, else None (replicate)."""
        return axis if divides_axis(dim, axis_size(self.mesh, axis)) else None

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # -- parameters -----------------------------------------------------------
    def param_spec(self, path: str, shape: tuple[int, ...]) -> P:
        cfg = self.cfg
        m = self.model

        def s(*names):
            return P(*names)

        # embeddings
        if path.endswith("embed/table"):
            return s(self.g(shape[0]), None)            # vocab over model
        if path.endswith("embed/unembed"):
            return s(None, self.g(shape[1]))
        # attention
        if re.search(r"(attn|cross)/wq$", path):
            return s(None, self.g(shape[1]), None)
        if re.search(r"(attn|cross)/w[kv]$", path):
            return s(None, self.g(shape[1]), None)      # replicates if kv<m
        if re.search(r"(attn|cross)/wo$", path):
            return s(self.g(shape[0]), None, None)
        if re.search(r"(attn|cross)/b[qkv]$", path):
            return s(self.g(shape[0]), None)
        # MLA
        if path.endswith("attn/wq_a"):
            return s(None, self.g(shape[1]))
        if path.endswith("attn/wq_b"):
            return s(None, self.g(shape[1]), None)
        if path.endswith("attn/wkv_a"):
            return s(None, None)
        if re.search(r"attn/w[kv]_b$", path):
            return s(None, self.g(shape[1]), None)      # heads over model
        # MoE
        if path.endswith("ffn/router"):
            return s(None, None)
        if re.search(r"ffn/w[ig]$", path) and len(shape) == 3:
            if cfg.moe and cfg.moe.num_experts % m == 0:
                return s("model", None, None)           # EP
            return s(None, None, self.g(shape[2]))      # TP inside experts
        if path.endswith("ffn/wo") and len(shape) == 3:
            if cfg.moe and cfg.moe.num_experts % m == 0:
                return s("model", None, None)
            return s(None, self.g(shape[1]), None)
        # dense MLP (incl. MoE shared experts)
        if re.search(r"(ffn|shared)/w[ig]$", path):
            return s(None, self.g(shape[1]))
        if re.search(r"(ffn|shared)/wo$", path):
            return s(self.g(shape[0]), None)
        # RG-LRU
        if re.search(r"rec/(w_gate|w_x)$", path):
            return s(None, self.g(shape[1]))
        if re.search(r"rec/(wa_gate|wx_gate)$", path):
            return s(self.g(shape[0]), None, None)      # gate blocks = heads
        if re.search(r"rec/conv_w$", path):
            return s(None, self.g(shape[1]))
        if path.endswith("rec/w_out"):
            return s(self.g(shape[0]), None)
        # SSD
        if re.search(r"ssd/(w_z|w_x)$", path):
            return s(None, self.g(shape[1]))
        if path.endswith("ssd/w_dt"):
            return s(None, self.g(shape[1]))
        if re.search(r"ssd/conv_x$", path):
            return s(None, self.g(shape[1]))
        if path.endswith("ssd/out_proj"):
            return s(self.g(shape[0]), None)
        # everything else (norms, biases, scalars, B/C projections) replicates
        return P()

    def param_specs(self, params) -> dict:
        def spec_of(path, leaf):
            key = "/".join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            shape = leaf.shape
            # embeddings stay model-sharded only (see DESIGN.md §5); norms/
            # scalars replicate; everything else may pick up an FSDP dim.
            skip_fsdp = ("embed/" in key or len(shape) < 2
                         or re.search(r"(ln\d|norm|_b$|bias)", key))
            # params stacked along a segment scan dim: rules see the per-layer
            # shape; prepend None for the stack dim.
            if self._is_stacked(key):
                inner = self.param_spec(key, shape[1:])
                if not skip_fsdp:
                    inner = self._add_fsdp(inner, shape[1:])
                return P(None, *inner)
            spec = self.param_spec(key, shape)
            if not skip_fsdp:
                spec = self._add_fsdp(spec, shape)
            return spec

        return jax.tree_util.tree_map_with_path(spec_of, params)

    def _is_stacked(self, key: str) -> bool:
        # segment params contain "/subN/" (stacked); top-level embed / norms
        # do not.
        return "/sub" in key

    # -- activations (shard callback for models.lm) ---------------------------
    def act_shard(self):
        def shard(x, name):
            if name == "act" and x.ndim == 3:
                dp = self._dp_for(x.shape[0])
                sp = "model" if (self.sp_activations
                                 and x.shape[1] % max(self.model, 1) == 0
                                 and x.shape[1] >= self.model) else None
                return jax.lax.with_sharding_constraint(
                    x, self.named(P(dp, sp, None)))
            if name == "mla_latent" and x.ndim == 3:
                # §Perf: force the sequence all-gather to happen on the
                # compressed latent (kv_lora+rope dims) — never on the
                # per-head expansion, which is ~42x larger on the wire.
                dp = self._dp_for(x.shape[0])
                return jax.lax.with_sharding_constraint(
                    x, self.named(P(dp, None, None)))
            if name == "q_heads" and x.ndim == 5:
                # pin head sharding through the attention reshapes so the
                # backward pass keeps dq/dk head-sharded instead of
                # all-reducing full-head gradients (§Perf it3)
                if not self.pin_attn_heads:
                    return x
                dp = self._dp_for(x.shape[0])
                return jax.lax.with_sharding_constraint(
                    x, self.named(P(dp, None, self.g(x.shape[2]), None,
                                    None)))
            if name == "kv_heads" and x.ndim == 4:
                if not self.pin_attn_heads:
                    return x
                dp = self._dp_for(x.shape[0])
                return jax.lax.with_sharding_constraint(
                    x, self.named(P(dp, None, self.g(x.shape[2]), None)))
            if name == "logits" and x.ndim == 3:
                dp = self._dp_for(x.shape[0])
                return jax.lax.with_sharding_constraint(
                    x, self.named(P(dp, None, self.g(x.shape[2]))))
            if name == "kv_compact" and x.ndim == 4:
                # §Perf: gather GQA kv across the sequence shards BEFORE the
                # repeat-to-H expansion (kv_heads << heads): the wire moves
                # the compact (B,S,K,E) form, the expansion stays local.
                dp = self._dp_for(x.shape[0])
                return jax.lax.with_sharding_constraint(
                    x, self.named(P(dp, None, self.g(x.shape[2]), None)))
            return x  # cache shardings are pinned via cache_spec

        return shard

    # -- batches ---------------------------------------------------------------
    def _dp_for(self, batch_dim: int):
        """dp axes if the batch dim divides them; else None (batch=1 cells)."""
        return self.dp if batch_dim % axis_size(self.mesh, self.dp) == 0 \
            else None

    def _seq_axes(self, batch_dim: int, seq_dim: int):
        """Sequence axis sharding for caches: when the batch can't shard
        (long-context batch=1), spread the sequence over the whole mesh."""
        if not self.seq_sharded_cache:
            return None
        candidates = ((("data", "model"),) if self._dp_for(batch_dim) is None
                      else ()) + (("model",), None)
        for cand in candidates:
            if cand is None:
                return None
            if seq_dim % axis_size(self.mesh, cand) == 0:
                return cand
        return None

    def batch_spec(self, batch_tree) -> dict:
        def spec_of(path, leaf):
            key = str(path[-1].key)
            if key == "positions" and len(leaf.shape) == 3:
                return P(None, self._dp_for(leaf.shape[1]), None)
            return P(self._dp_for(leaf.shape[0]),
                     *([None] * (len(leaf.shape) - 1)))

        return jax.tree_util.tree_map_with_path(spec_of, batch_tree)

    # -- caches ------------------------------------------------------------------
    def cache_spec(self, cache_tree) -> dict:
        """Decode caches: batch over dp (when divisible); kv sequence axis
        over model — or over the whole mesh for unsharded-batch long-context
        cells (context parallelism); recurrent states shard channels/heads
        over model.  Leading dim of every leaf is the segment scan stack."""

        def spec_of(path, leaf):
            key = str(path[-1].key)
            nd = len(leaf.shape)
            if key in ("k", "v", "ck", "cv"):        # (L,B,T,K,E)
                dp = self._dp_for(leaf.shape[1])
                if (self.head_sharded_cache
                        and leaf.shape[3] % max(self.model, 1) == 0):
                    return P(None, dp, None, "model", None)
                seq = self._seq_axes(leaf.shape[1], leaf.shape[2])
                return P(None, dp, seq, None, None)
            if key == "ckv" or key == "krope":       # (L,B,T,R)
                dp = self._dp_for(leaf.shape[1])
                seq = self._seq_axes(leaf.shape[1], leaf.shape[2])
                return P(None, dp, seq, None)
            dp = self._dp_for(leaf.shape[1])
            if key == "h" and nd == 3:               # rec state (L,B,Dr)
                return P(None, dp, self.g(leaf.shape[2]))
            if key == "h" and nd == 5:               # ssd state (L,B,H,N,P)
                return P(None, dp, self.g(leaf.shape[2]), None, None)
            if key in ("x",):                        # ssd conv state (L,B,W,D)
                return P(None, dp, None, self.g(leaf.shape[3]))
            if key in ("b", "c"):
                return P(None, dp, None, None)
            if key == "conv" and nd == 4:            # rec conv (L,B,W,Dr)
                return P(None, dp, None, self.g(leaf.shape[3]))
            return P(None, dp, *([None] * (nd - 2)))

        return jax.tree_util.tree_map_with_path(spec_of, cache_tree)

    # -- train state ---------------------------------------------------------------
    def state_spec(self, state) -> dict:
        pspecs = self.param_specs(state["params"])
        return {
            "params": pspecs,
            "opt": {
                "m": pspecs,
                "v": pspecs,
                "step": P(),
            },
        }

    def to_shardings(self, spec_tree):
        return jax.tree.map(
            lambda sp: self.named(sp), spec_tree,
            is_leaf=lambda x: isinstance(x, P))
