"""Distribution layer: sharding rules, pipeline parallelism, collectives."""
