"""Distribution layer: sharding rules, pipeline parallelism, collectives.

Two consumers sit on top of this package (see ``sharding.py``):

  * the serving/training side — ``launch/dryrun.py`` builds production
    meshes and resolves ``Rules`` PartitionSpecs for the LM step functions;
  * the dataplane side — ``switchsim/fabric.py`` shard_maps the engine's
    flat pipe axis over a 1-D ``("switch",)`` mesh (DESIGN.md §12).

``force_host_devices`` is the ONE sanctioned way to get multi-device CPU
runs (the SNIPPETS.md ``--xla_force_host_platform_device_count`` recipe):
it must run before jax initializes a backend, and it *raises* when called
too late instead of silently mutating an env var jax has already read —
the bug the seed-era ``launch/dryrun.py`` header carried.
"""
from __future__ import annotations

import os
import sys

_FORCE_FLAG = "--xla_force_host_platform_device_count"


def jax_backend_initialized() -> bool:
    """True once jax has initialized any backend — the point at which the
    platform device count is locked and XLA_FLAGS edits stop working.

    Importing jax does NOT initialize a backend; the first operation that
    touches devices (``jax.devices()``, any traced computation) does.
    Kept dependency-light: never imports jax itself, only inspects an
    already-imported module, so calling this cannot trigger the very
    initialization it checks for.
    """
    jax_mod = sys.modules.get("jax")
    if jax_mod is None:
        return False
    try:
        backends = jax_mod._src.xla_bridge._backends
    except AttributeError:
        # unknown jax internals: assume the worst (initialized) so callers
        # fail loudly rather than silently run on the wrong device count
        return True
    return bool(backends)


def force_host_devices(n: int) -> None:
    """Force the CPU platform to expose ``n`` devices (XLA_FLAGS recipe).

    Prepends ``--xla_force_host_platform_device_count=n`` to ``XLA_FLAGS``
    (replacing any previous occurrence) so CPU-only hosts — CI included —
    exercise *real* multi-device sharding: ``switchsim/fabric.py`` meshes,
    the dry-run's 512-chip mesh, the forced-host distributed tests.

    Raises ``RuntimeError`` if jax has already initialized a backend: the
    device count is locked at first backend init, so a late call would be
    a silent no-op — exactly the hazard this helper exists to remove
    (``launch/dryrun.py`` used to mutate the env var inline and hope it
    ran first).  Call it before anything touches jax devices: entry-point
    tops, subprocess preludes, benchmark ``--host-devices`` flags.
    """
    n = int(n)
    if n < 1:
        raise ValueError(f"device count must be >= 1, got {n}")
    if jax_backend_initialized():
        raise RuntimeError(
            "force_host_devices called after jax initialized a backend — "
            "the host device count is locked at first init and XLA_FLAGS "
            "is no longer read.  Call it before any jax device use "
            "(or launch a fresh process / set XLA_FLAGS="
            f"{_FORCE_FLAG}={n} in the environment).")
    kept = [f for f in os.environ.get("XLA_FLAGS", "").split()
            if not f.startswith(_FORCE_FLAG)]
    os.environ["XLA_FLAGS"] = " ".join([f"{_FORCE_FLAG}={n}"] + kept).strip()
