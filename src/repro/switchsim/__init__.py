"""Switch + NF-server performance simulation (paper §6 methodology)."""
