"""Fault-injection layer for the engine scan (DESIGN.md §10).

Faults are *data*, not compile-time constants: a ``FaultSpec`` describes a
single fault event declaratively, and ``resolve`` lowers it to per-step
boolean masks the engine threads through its ``lax.scan`` as extra xs —
``server_up`` (per pipe: is this pipe's NF server reachable at step t?)
and ``lb_up`` (is the Maglev fault target's backend machine alive at step
t?) — plus a per-pipe ``drain`` flag selecting the failover semantics for
packets lost at a dead server.  All-True masks are bit-exact no-ops on the
step body, so ONE compiled program serves both faulted and healthy
scenarios; fault timing never forces a recompile and faulted points batch
with healthy ones in the scenario runner's compile groups (DESIGN.md §8).

Two fault kinds:

  * ``server`` — the NF server behind pipe ``pipe`` stops answering for
    ``duration`` steps starting at ``start``.  Packets the switch sends
    during the outage are lost (``fault_drops`` counter); the parked
    payloads they left behind either *drain* (``drain=True``: the failover
    agent emits OP=drop notifications on the return path, the §6.2.4
    Explicit-Drop machinery frees the slots at Merge) or *drop*
    (``drain=False``: the slots leak until expiry-based eviction reclaims
    them — the degradation the adversarial family's recovery gate bounds).
  * ``lb`` — backend ``backend`` of the Maglev LB dies for the fault
    window.  ``MaglevLB(fault_target=...)`` pre-builds the degraded
    lookup table; the mask only *selects* between the two tables, so the
    kill->recover round trip is pure data flow.

Masks are defined over the *offered* trace steps; the engine pads them
with True (a fault cannot outlive the traffic that observes it — enforced
by ``ScenarioSpec``), so drain/warm-up padding always runs healthy.
"""
from __future__ import annotations

import dataclasses

import numpy as np

FAULT_KINDS = ("none", "server", "lb")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One declarative fault event (frozen + hashable, like ScenarioSpec).

    ``kind="none"`` (the default) is the healthy run; ``start``/``duration``
    are engine steps; ``pipe`` selects the victim pipe for ``server``
    faults; ``backend`` the victim Maglev backend for ``lb`` faults;
    ``drain`` picks the drain-vs-drop failover rule (server faults only).
    """

    kind: str = "none"
    start: int = 0
    duration: int = 0
    pipe: int = 0
    backend: int = 0
    drain: bool = False

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r} (have {FAULT_KINDS})")
        if self.start < 0 or self.duration < 0:
            raise ValueError(
                f"fault start/duration must be >= 0, got "
                f"({self.start}, {self.duration})")
        if self.pipe < 0 or self.backend < 0:
            raise ValueError("fault pipe/backend must be >= 0")

    @property
    def active(self) -> bool:
        return self.kind != "none" and self.duration > 0

    @property
    def end(self) -> int:
        return self.start + self.duration


NO_FAULT = FaultSpec()


@dataclasses.dataclass
class FaultArrays:
    """Lowered per-step masks: ``server_up``/``lb_up`` are (P, S) bool,
    ``drain`` is (P,) bool.  The scenario runner concatenates these along
    the pipe axis exactly like the traces when it batches compile-compatible
    points (DESIGN.md §8)."""

    server_up: np.ndarray
    lb_up: np.ndarray
    drain: np.ndarray

    @property
    def pipes(self) -> int:
        return self.server_up.shape[0]

    @property
    def steps(self) -> int:
        return self.server_up.shape[1]


def pipe_masks(fault: FaultSpec | None, pipe: int,
               steps: int) -> tuple[np.ndarray, np.ndarray, bool]:
    """Lower one fault event to the masks ONE pipe's scan consumes.

    Returns ``(server_up (S,), lb_up (S,), drain)``.  ``lb`` faults are
    global (every pipe's LB instance watches the same backend machine);
    ``server`` faults hit only the named pipe.
    """
    fault = NO_FAULT if fault is None else fault
    s_up = np.ones(steps, bool)
    l_up = np.ones(steps, bool)
    lo, hi = fault.start, min(fault.end, steps)
    if fault.active and lo < hi:
        if fault.kind == "server" and fault.pipe == pipe:
            s_up[lo:hi] = False
        elif fault.kind == "lb":
            l_up[lo:hi] = False
    return s_up, l_up, bool(fault.drain)


def resolve(faults, pipes: int, steps: int) -> FaultArrays:
    """FaultSpec | FaultArrays | None -> validated FaultArrays."""
    if isinstance(faults, FaultArrays):
        if faults.pipes != pipes or faults.steps != steps:
            raise ValueError(
                f"fault masks shaped {faults.server_up.shape} do not match "
                f"(pipes={pipes}, steps={steps})")
        return faults
    rows = [pipe_masks(faults, p, steps) for p in range(pipes)]
    return FaultArrays(
        server_up=np.stack([r[0] for r in rows]),
        lb_up=np.stack([r[1] for r in rows]),
        drain=np.array([r[2] for r in rows], bool),
    )


def concat(arrays: list[FaultArrays]) -> FaultArrays:
    """Stack per-scenario masks along the pipe axis (runner batching)."""
    return FaultArrays(
        server_up=np.concatenate([a.server_up for a in arrays], axis=0),
        lb_up=np.concatenate([a.lb_up for a in arrays], axis=0),
        drain=np.concatenate([a.drain for a in arrays], axis=0),
    )
