"""Streaming steady-state driver: constant-memory runs over chunked sources.

The materialized engine (``switchsim.engine``) compiles the whole timeline
into one ``lax.scan`` — which also means the whole trace, its merged output
and every per-step ys live at once.  That caps a run at what fits in memory
(~minutes of simulated traffic) and makes steady-state questions — tail
latency under diurnal load, occupancy drift over millions of packets —
unanswerable.  This module is the long-haul path (DESIGN.md §13):

  * The trace arrives as a ``traffic.stream.TraceSource``; only one
    ``segment_len``-step slice of packets is ever live.
  * One SEGMENT program is jitted with ``donate_argnums`` on the carry —
    the switch state, NF-chain state, in-flight ring, recirculation lane
    and telemetry accumulators are donated back each call, so device
    memory for a 10^9-step run equals that of a single segment.
  * The per-step body is ``engine.scan_step`` — the *same* traced function
    the materialized engine scans.  Segment-replay bit-exactness
    (``replay_oracle``) therefore holds by construction: there is one step
    body, not two maintained in parallel.
  * What survives a segment is O(1): a (len(TEL_FIELDS),) int32 telemetry
    sum (accumulated host-side in int64 across segments), the per-step
    occupancy series of that segment (summarized to min/mean/max/last),
    and a fixed-size reservoir of sojourn-time samples.

Latency model (recorded deviation, DESIGN.md §13): the simulator is
step-quantized, so per-packet sojourn is reconstructed, not measured.  A
packet split at step ``t`` merges at ``t + window``; the paper puts the
split->merge dwell at ~30 us (§4), so one step is ``30 us / window`` and a
merged row's sojourn is ``window`` steps — ``window + 1`` for rows that
took the recirculation lane (one extra pass; lane rows lead each merged
chunk, so the extra step is statically position-determined).  Serialization
adds 0.8 ns/byte (10 Gbps).  All integer ns: the reservoir, the quantiles
and the offline oracle (tests/test_streaming.py) compute on exact ints.

The reservoir is Algorithm R with a counter-based splitmix32 coin: sample
number ``n`` lands in slot ``n`` while filling, then in slot
``splitmix32(seed ^ n * phi) % (n + 1)`` (kept only if ``< K``).  Within a
step the chunk's samples are inserted in row order with last-writer-wins
slot conflicts (a deterministic scatter-max), which is exactly sequential
Algorithm R under that coin — replayable bit-for-bit, no RNG state in the
carry.  Expected quantile error is the classic reservoir bound
O(sqrt(q(1-q)/K)); K=4096 puts ~1 sigma at p99 under 0.16 pp of rank.

Faults are NOT supported on this path (recorded deviation): fault windows
are phrased over a whole materialized run; streaming runs are healthy,
masks pinned all-True.  Use ``run_engine``/``run_pipes`` for fault studies.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro.backend import coerce_backend
from repro.core import counters as C
from repro.core.park import ParkConfig
from repro.nf.chain import Chain
from repro.switchsim.engine import (_nf_counters, init_carry, recirc_slots,
                                    scan_step)
from repro.switchsim.results import StreamResult
from repro.switchsim.telemetry import TEL_FIELDS, LinkTelemetry
from repro.traffic.stream import (MaterializedSource, SyntheticSource,
                                  TraceSource, as_source, splitmix32)

__all__ = ["run_stream", "replay_oracle", "StreamOracleMismatch",
           "sojourn_ns", "step_ns_for", "SPLIT_MERGE_NS"]

# Paper §4: the split->merge dwell a parked payload spends in the switch is
# ~30 us end to end; the scan spreads it over ``window`` steps.
SPLIT_MERGE_NS = 30_000


def step_ns_for(window: int) -> int:
    """Integer ns one scan step stands for under the §4 dwell model."""
    return max(1, round(SPLIT_MERGE_NS / max(window, 1)))


def sojourn_ns(pkt_len, recirculated, window: int, step_ns: int):
    """Reconstructed per-packet sojourn in integer ns: dwell steps
    (``window``, +1 for a recirculation-lane pass) plus 0.8 ns/byte
    serialization (10 Gbps).  Pure integer math — the offline oracle in
    tests recomputes it exactly."""
    steps = jnp.asarray(window, jnp.int32) + jnp.asarray(
        recirculated, jnp.int32)
    return steps * jnp.int32(step_ns) + \
        (jnp.asarray(pkt_len, jnp.int32) * 4) // 5


def _reservoir_insert(vals, n, sample, alive, seed: int):
    """One chunk of samples through Algorithm R, sequential semantics.

    ``vals`` is the (K,) int32 reservoir, ``n`` the int32 count of samples
    seen so far, ``sample``/``alive`` the chunk's candidate rows.  Sample
    number ``m`` (0-based, global) goes to slot ``m`` while ``m < K``, else
    to ``splitmix32(seed ^ m*phi) % (m+1)`` and is kept only if that lands
    below K.  Row-order conflicts resolve last-writer-wins via a
    deterministic scatter-max over row indices — identical to processing
    the rows one at a time.
    """
    k = vals.shape[0]
    rows = alive.shape[0]
    pos = jnp.cumsum(alive.astype(jnp.int32)) - 1
    m = n + pos  # global sample number of each alive row
    h = splitmix32(jnp.uint32(seed) ^
                   (m.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)))
    j = jnp.where(m < k, m,
                  (h % jnp.maximum(m + 1, 1).astype(jnp.uint32))
                  .astype(jnp.int32))
    write = alive & (j < k)
    dest = jnp.where(write, j, k)
    winner = jnp.full((k + 1,), -1, jnp.int32)
    winner = winner.at[dest].max(jnp.arange(rows, dtype=jnp.int32))[:k]
    take = winner >= 0
    vals = jnp.where(take, sample[jnp.where(take, winner, 0)], vals)
    return vals, n + jnp.sum(alive.astype(jnp.int32))


@lru_cache(maxsize=None)
def _segment_program(cfg: ParkConfig, chain: Chain, window: int,
                     explicit_drops: bool, backend, recirc: int,
                     step_ns: int, res_seed: int):
    """The donated-carry segment: scan ``engine.scan_step`` over a
    (S, chunk, ...) slice, fold each step's merged chunk into the
    reservoir, and return O(1) per-segment aggregates.

    jit specializes per segment shape (the steady segment, one ragged
    tail, one drain pad), so the cache key here is the compile config
    only — mirroring ``engine._compiled``.
    """
    step = scan_step(cfg, chain, window, explicit_drops, backend,
                     collect_sent=False, recirc=recirc)

    def seg(carry, trace, server_up, lb_up, drain):
        core, vals, n = carry

        def body(c, xs):
            core, vals, n = c
            core, ys = step(core, xs, drain)
            m = ys["merged"]
            lane_rows = jnp.arange(m.alive.shape[0]) < recirc
            sample = sojourn_ns(m.pkt_len(), lane_rows, window, step_ns)
            vals, n = _reservoir_insert(vals, n, sample, m.alive, res_seed)
            tel = jnp.stack([ys[f] for f in TEL_FIELDS])
            return (core, vals, n), (tel, ys["occ"])

        (core, vals, n), (tels, occ) = jax.lax.scan(
            body, (core, vals, n), (trace, server_up, lb_up))
        # int32 per-segment totals (bounded by the run_stream guard);
        # run_stream accumulates them host-side in int64 across segments.
        return (core, vals, n), tels.sum(axis=0), occ

    return jax.jit(seg, donate_argnums=(0,))


def _occ_summary(start: int, occ: np.ndarray) -> dict:
    return dict(start=int(start), steps=int(occ.shape[0]),
                min=int(occ.min()), mean=float(occ.mean()),
                max=int(occ.max()), last=int(occ[-1]))


def _quantiles_us(vals: np.ndarray, n: int) -> dict:
    """Tail-latency block from the reservoir: nearest-rank quantiles of the
    valid prefix (slots fill in order while n < K), reported in µs."""
    k = vals.shape[0]
    out = dict(samples=int(n), reservoir=int(k))
    valid = np.sort(vals[:min(n, k)].astype(np.int64))
    if valid.size:
        for name, q in (("p50_us", 0.50), ("p99_us", 0.99),
                        ("p999_us", 0.999)):
            out[name] = float(np.quantile(valid, q, method="nearest")) / 1e3
    return out


def run_stream(
    cfg: ParkConfig,
    chain: Chain,
    source,
    window: int = 1,
    segment_len: int = 256,
    explicit_drops: bool = False,
    backend=None,
    reservoir: int = 4096,
    reservoir_seed: int = 0x5EED,
) -> StreamResult:
    """Run one pipe over a ``TraceSource`` at constant memory.

    The source is consumed ``segment_len`` steps at a time through one
    jitted segment program whose carry (switch state, NF-chain state,
    in-flight ring, recirculation lane, reservoir) is donated back each
    call; after the last segment a drain pad of all-dead chunks flushes the
    in-flight window (and, with recirculation, the lane) exactly as the
    materialized engine's trace padding does.  Counters, telemetry,
    nf_counters and peak occupancy are bit-identical to
    ``run_engine(cfg, chain, source.materialize(), ...)`` — enforced by
    ``replay_oracle`` and tests/test_streaming.py.

    On top of the materialized facts, the stream keeps what a materialized
    run cannot afford at this length: a ``reservoir``-slot sample of
    per-packet sojourn times (p50/p99/p999 in the ``latency`` block) and
    per-segment occupancy summaries (``occ_segments``).

    Faults are not supported here (healthy masks only); use the
    materialized entry points for fault studies.
    """
    backend = coerce_backend(backend)
    source = as_source(source)
    if source.steps < 1:
        raise ValueError("streaming needs a source with >= 1 step")
    if segment_len < 1:
        raise ValueError(f"segment_len must be >= 1, got {segment_len}")
    if reservoir < 1:
        raise ValueError(f"reservoir must be >= 1, got {reservoir}")
    chunk = source.chunk
    # Per-segment telemetry sums are int32 on device: bound the worst-case
    # byte sum (every row alive at max frame size) under 2^31.
    frame = source.pmax + 64
    if segment_len * chunk * frame >= 2**31:
        raise ValueError(
            f"segment_len {segment_len} overflows int32 telemetry "
            f"(chunk={chunk}, pmax={source.pmax}); use shorter segments")
    lane = recirc_slots(cfg, chunk)
    pad = window + (1 if lane else 0)
    step_ns = step_ns_for(window)
    fn = _segment_program(cfg, chain, window, explicit_drops, backend,
                          lane, step_ns, reservoir_seed)
    chunk_like = jax.tree.map(lambda a: a[0], source.segment(0, 1))
    carry = (init_carry(cfg, chain, chunk_like, window, lane),
             jnp.zeros((reservoir,), jnp.int32),
             jnp.zeros((), jnp.int32))
    drain = jnp.asarray(False)
    tel_total = np.zeros((len(TEL_FIELDS),), np.int64)
    occ_segments: list[dict] = []
    peak = 0
    n_segments = 0
    with warnings.catch_warnings():
        # CPU/backends without buffer donation warn per call; the fallback
        # is a copy, not an error, and the run stays correct.
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        for start in range(0, source.steps, segment_len):
            n = min(segment_len, source.steps - start)
            ones = jnp.ones((n,), bool)
            carry, tel, occ = fn(carry, source.segment(start, n),
                                 ones, ones, drain)
            tel_total += np.asarray(tel, np.int64)
            occ = np.asarray(occ, np.int64)
            occ_segments.append(_occ_summary(start, occ))
            peak = max(peak, int(occ.max()))
            n_segments += 1
        if pad:
            dead = jax.tree.map(
                lambda a: jnp.zeros((pad,) + a.shape, a.dtype), chunk_like)
            ones = jnp.ones((pad,), bool)
            carry, tel, occ = fn(carry, dead, ones, ones, drain)
            tel_total += np.asarray(tel, np.int64)
            occ = np.asarray(occ, np.int64)
            occ_segments.append(_occ_summary(source.steps, occ))
            peak = max(peak, int(occ.max()))
    (state, cstates, _, _, _), vals, n_samples = carry
    tel = LinkTelemetry(**{f: int(v)
                           for f, v in zip(TEL_FIELDS, tel_total)})
    return StreamResult(
        state=state,
        counters=C.as_dict(state.counters),
        telemetry=tel,
        nf_counters=_nf_counters(chain, cstates),
        peak_occupancy=peak,
        latency=_quantiles_us(np.asarray(vals), int(n_samples)),
        occ_segments=occ_segments,
        steps=source.steps,
        segments=n_segments,
        segment_len=segment_len,
    )


class StreamOracleMismatch(AssertionError):
    """Streaming and materialized engines disagreed on exact facts."""


def _prefix_source(source: TraceSource, steps: int) -> TraceSource:
    """The same source truncated to its first ``steps`` steps — without
    materializing when the source can re-scope itself."""
    if steps == source.steps:
        return source
    if not 0 < steps <= source.steps:
        raise ValueError(f"prefix {steps} outside (0, {source.steps}]")
    if isinstance(source, SyntheticSource):
        # chunk t is a pure function of (seed, t): re-scoping the length
        # changes nothing about the steps that remain
        return dataclasses.replace(source, steps=steps)
    return MaterializedSource(source.segment(0, steps))


def replay_oracle(
    cfg: ParkConfig,
    chain: Chain,
    source,
    window: int = 1,
    segment_len: int = 64,
    segments: int = 4,
    explicit_drops: bool = False,
    backend=None,
) -> dict:
    """The segment-replay bit-exactness gate (DESIGN.md §13).

    Streams the first ``segments`` consecutive segments of ``source`` and
    runs the materialized engine (``run_pipes``, one pipe) over the same
    concatenated chunks; counters, full per-link telemetry, NF-private
    counters and peak occupancy must match EXACTLY — the streaming path
    shares ``engine.scan_step``, so any drift is a carry-threading or
    accumulation bug, never tolerance.  Raises ``StreamOracleMismatch``
    with every differing fact; returns a small report when clean.
    """
    from repro.switchsim.engine import run_pipes
    source = as_source(source)
    steps = min(source.steps, segment_len * segments)
    prefix = _prefix_source(source, steps)
    sres = run_stream(cfg, chain, prefix, window=window,
                      segment_len=segment_len,
                      explicit_drops=explicit_drops, backend=backend)
    mres = run_pipes(cfg, chain, prefix, window=window,
                     explicit_drops=explicit_drops, backend=backend)
    diffs = []
    for name, a, b in (("counters", sres.counters, mres.counters),
                       ("telemetry", sres.telemetry.as_dict(),
                        mres.telemetry.as_dict()),
                       ("nf_counters", sres.nf_counters, mres.nf_counters)):
        for k in sorted(set(a) | set(b)):
            if a.get(k) != b.get(k):
                diffs.append(f"{name}.{k}: stream={a.get(k)} "
                             f"materialized={b.get(k)}")
    if sres.peak_occupancy != mres.peak_occupancy:
        diffs.append(f"peak_occupancy: stream={sres.peak_occupancy} "
                     f"materialized={mres.peak_occupancy}")
    if diffs:
        raise StreamOracleMismatch(
            f"segment replay diverged over {steps} steps "
            f"({len(diffs)} facts):\n  " + "\n  ".join(diffs))
    return dict(steps=steps, packets=steps * source.chunk,
                segments=min(segments,
                             -(-steps // segment_len)),
                wire_bytes=sres.wire_bytes)
