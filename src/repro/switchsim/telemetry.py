"""Per-link byte/packet telemetry for the switch<->server wire (DESIGN.md §7).

The engine's original accounting was three aggregate byte totals (wire in,
server link both directions, merged out) — enough for goodput arithmetic but
too coarse to model what actually *arrives at the NF server*: the host model
(``repro.hostmodel``) needs per-direction byte AND packet counts, because
PCIe/DMA cost has a per-packet component (TLP headers, descriptor fetches)
on top of the per-byte one (pcie-bench; NFSlicer, PAPERS.md).

``LinkTelemetry`` is that struct: exact int totals for every link a packet
can traverse in one pipe —

  * ``wire``        generator -> switch ingress (every offered packet);
  * ``to_server``   switch -> server, post-Split (header-only for parked
                    packets, full packet + 7B PP header for ENB=0);
  * ``from_server`` server -> switch, the returning direction (NF-chain
                    survivors, still header-only when parked);
  * ``recirc``      the recirculation port (packets admitted into the
                    engine's lane, paper §6.2.5);
  * ``merged``      switch egress after Merge (full packets again).

Under §6.3.2 steering one pipe fronts one NF server, so per-pipe telemetry
IS per-server telemetry: ``PipesResult.per_pipe_telemetry`` feeds the
host model's per-server PCIe/DMA accounting directly.

The engine accumulates these on-device as per-step int32 ys, summed
host-side in int64; ``simulate_loop`` mirrors the accumulation points
exactly, so the engine≡loop bit-exactness oracle (tests/test_engine.py,
tests/test_recirc.py) covers the telemetry too.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LinkTelemetry:
    """Exact per-link totals for one pipe (or the cross-pipe sum).

    All fields are plain ints; ``bytes`` count on-wire bytes of alive
    packets (42B header + optional 7B PP header + payload), ``pkts`` count
    alive packets, at the same accumulation point.
    """

    wire_pkts: int = 0
    wire_bytes: int = 0
    to_server_pkts: int = 0
    to_server_bytes: int = 0
    from_server_pkts: int = 0
    from_server_bytes: int = 0
    recirc_pkts: int = 0
    recirc_bytes: int = 0
    merged_pkts: int = 0
    merged_bytes: int = 0

    @property
    def srv_bytes(self) -> int:
        """Server-link bytes, both directions (the goodput denominator)."""
        return self.to_server_bytes + self.from_server_bytes

    @property
    def srv_pkts(self) -> int:
        return self.to_server_pkts + self.from_server_pkts

    def as_dict(self) -> dict[str, int]:
        return dataclasses.asdict(self)

    def __add__(self, other: "LinkTelemetry") -> "LinkTelemetry":
        if not isinstance(other, LinkTelemetry):
            return NotImplemented
        return LinkTelemetry(**{
            f.name: getattr(self, f.name) + getattr(other, f.name)
            for f in dataclasses.fields(LinkTelemetry)})


# Field names in declaration order — the single source of truth for the
# engine's ys keys and the loop mirrors' accumulator keys.
TEL_FIELDS = tuple(f.name for f in dataclasses.fields(LinkTelemetry))


def sum_telemetry(parts) -> LinkTelemetry:
    """Cross-pipe aggregation: the ToR-level totals of per-server links."""
    total = LinkTelemetry()
    for p in parts:
        total = total + p
    return total
