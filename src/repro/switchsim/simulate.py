"""Event-driven pipeline simulation running the *real* core state machine.

The analytic model (perfmodel.py) predicts rates; this module validates the
*stateful* behaviour — eviction dynamics, premature-eviction onset, Explicit
Drop reclamation, functional equivalence — by streaming packets through the
actual ``core.park`` Split/Merge implementation with a configurable in-flight
window, the simulated analogue of the paper's split->merge time-delta
(~30 us, §4).

Timeline model: packets are processed in chunks (the switch interleaves Split
and Merge traffic); chunk ``t`` is split at step ``t`` and its NF-chain output
returns for merging at step ``t + window`` — i.e. ``window * chunk`` packets
are in flight, exactly the quantity that pressures the lookup table
(M * EXP >= in_flight for eviction-free operation, §4).

Two implementations share these semantics bit-for-bit:

  * ``simulate()`` — the list-of-chunks API, now a thin wrapper over the
    jit-compiled ``switchsim.engine`` scan (DESIGN.md §3).  Same signature
    and ``SimResult`` as the seed; the whole timeline runs as one XLA
    program instead of a host loop with per-chunk device syncs.
  * ``simulate_loop()`` — the original host-side Python chunk loop, kept as
    the executable reference: ``tests/test_engine.py`` asserts the scanned
    engine reproduces it wire-identically, and ``benchmarks/bench_pipeline``
    reports the speedup over it.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.backend import coerce_backend
from repro.core import counters as C
from repro.core.packet import PacketBatch, dead_batch, to_time_major
from repro.core.park import ParkConfig, init_state, merge, recirc, split
from repro.nf.chain import Chain, to_explicit_drops
from repro.switchsim import engine as engine_mod
from repro.switchsim import faults as F
from repro.switchsim.results import SimResult
from repro.switchsim.telemetry import TEL_FIELDS, LinkTelemetry

__all__ = ["SimResult", "simulate", "simulate_loop", "baseline_roundtrip"]


def _chunks(pkts: PacketBatch, chunk: int):
    n = pkts.batch_size
    assert n % chunk == 0, (n, chunk)
    return [
        jax.tree.map(lambda a: a[i: i + chunk], pkts)
        for i in range(0, n, chunk)
    ]


def _alive_stats(p: PacketBatch) -> tuple[int, int]:
    """(alive packets, alive on-wire bytes) — the loop-side mirror of the
    engine's per-step telemetry tallies, fetched in one device->host sync."""
    pair = np.asarray(jnp.stack([
        jnp.sum(p.alive.astype(jnp.int32)),
        jnp.sum(jnp.where(p.alive, p.pkt_len(), 0))]))
    return int(pair[0]), int(pair[1])


def simulate(
    cfg: ParkConfig,
    chain: Chain,
    pkts: PacketBatch,
    window: int = 1,
    chunk: int = 256,
    explicit_drops: bool = False,
    backend=None,
    faults=None,
) -> SimResult:
    """Stream ``pkts`` through split -> NF chain -> merge with ``window``
    chunks in flight.  Returns every merged chunk plus final switch state.

    Compatibility wrapper: delegates to the scanned engine (one compiled
    program, on-device accounting) and re-materializes the list-of-chunks
    view the seed API exposed.  ``backend`` selects the hot-path primitive
    implementations (``repro.backend``); ``faults`` a ``faults.FaultSpec``
    fault event (DESIGN.md §10).
    """
    backend = coerce_backend(backend)
    trace = to_time_major(pkts, chunk)
    res = engine_mod.run_engine(
        cfg, chain, trace, window=window, explicit_drops=explicit_drops,
        backend=backend, collect_sent=True, faults=faults)
    t = res.merged.src_ip.shape[0]  # == trace steps (+1 recirc drain step)
    merged = [jax.tree.map(lambda a: a[i], res.merged) for i in range(t)]
    sent = [jax.tree.map(lambda a: a[i], res.sent) for i in range(t)]
    return SimResult(
        merged=merged,
        state=res.state,
        sent_to_server=sent,
        counters=res.counters,
        srv_bytes=res.srv_bytes,
        wire_bytes=res.wire_bytes,
        ret_bytes=res.ret_bytes,
        telemetry=res.telemetry,
        nf_counters=res.nf_counters,
    )


def simulate_loop(
    cfg: ParkConfig,
    chain: Chain,
    pkts: PacketBatch,
    window: int = 1,
    chunk: int = 256,
    explicit_drops: bool = False,
    backend=None,
    faults=None,
    fault_pipe: int = 0,
) -> SimResult:
    """The seed host-side chunk loop (reference implementation).

    One jitted dispatch per chunk per operation plus a device->host sync for
    every byte tally — the dispatch overhead the scanned engine removes.
    Kept as the behavioural oracle for ``simulate()`` / the engine; with
    ``cfg.recirculation`` it mirrors the engine's recirculation lane
    host-side (``_simulate_loop_recirc``) and stays the oracle there too.
    The loop dispatches the SAME per-primitive backend as the engine, so
    the engine≡loop invariant is asserted per backend.

    ``faults`` (``faults.FaultSpec``) mirrors the engine's fault-injection
    xs host-side (DESIGN.md §10): the loop IS the oracle through fault
    events too.  ``fault_pipe`` names which pipe of a multi-pipe scenario
    this single-pipe loop replays (a ``server`` fault only hits its victim
    pipe's masks).
    """
    backend = coerce_backend(backend)
    if engine_mod.recirc_slots(cfg, chunk) > 0:
        return _simulate_loop_recirc(cfg, chain, pkts, window, chunk,
                                     explicit_drops, backend, faults,
                                     fault_pipe)
    state = init_state(cfg)
    chain_states = chain.init_state()
    inflight: list = []
    merged: list = []
    sent: list = []
    tel = dict.fromkeys(TEL_FIELDS, 0)  # recirc_* stay 0: lane off

    todo = _chunks(pkts, chunk)
    s_up, l_up, drain = F.pipe_masks(faults, fault_pipe, len(todo))
    steps = len(todo) + window
    for t in range(steps):
        if t < len(todo):
            cin = todo[t]
            p, b = _alive_stats(cin)
            tel["wire_pkts"] += p
            tel["wire_bytes"] += b
            state, out = split(cfg, state, cin, backend=backend)
            sent.append(out)
            p, b = _alive_stats(out)
            tel["to_server_pkts"] += p
            tel["to_server_bytes"] += b
            # fault mirror (engine step order): kill at send time, run the
            # chain on the survivors, then the drain-vs-drop notification
            killed = out.alive & ~jnp.asarray(s_up[t])
            state = dataclasses.replace(
                state, counters=C.bump(state.counters, "fault_drops",
                                       jnp.sum(killed)))
            srv_in = out.replace(alive=out.alive & jnp.asarray(s_up[t]))
            chain_states, nf_out, dropped, _cycles = chain.run(
                chain_states, srv_in, backend=backend,
                ctx={"lb_up": jnp.asarray(l_up[t])})
            if explicit_drops:
                nf_out = to_explicit_drops(nf_out, dropped)
            nf_out = to_explicit_drops(nf_out, killed & drain)
            inflight.append(nf_out)
        if t >= window and (t - window) < len(inflight):
            returning = inflight[t - window]
            p, b = _alive_stats(returning)
            tel["from_server_pkts"] += p
            tel["from_server_bytes"] += b
            state, m = merge(cfg, state, returning, backend=backend)
            merged.append(m)
            p, b = _alive_stats(m)
            tel["merged_pkts"] += p
            tel["merged_bytes"] += b

    telemetry = LinkTelemetry(**tel)
    return SimResult(
        merged=merged,
        state=state,
        sent_to_server=sent,
        counters=C.as_dict(state.counters),
        srv_bytes=telemetry.srv_bytes,
        wire_bytes=telemetry.wire_bytes,
        ret_bytes=telemetry.merged_bytes,
        telemetry=telemetry,
        nf_counters={k: int(v) for k, v in
                     chain.state_counters(chain_states).items()},
    )


def _simulate_loop_recirc(cfg, chain, pkts, window, chunk, explicit_drops,
                          backend, faults=None, fault_pipe: int = 0):
    """Host-side mirror of the engine's recirculation timeline (DESIGN.md
    §6): same op order (recirc pass, Split, budget admission, NF, ring,
    Merge), same lane width, one drain step — kept as the executable oracle
    for the scanned engine with recirculation on.  Fault masks are mirrored
    exactly as in ``simulate_loop`` (padding steps run healthy — but note
    lane re-injections DO traverse the server link on padding steps, which
    is why both sides pad the masks rather than skip the kill)."""
    state = init_state(cfg)
    chain_states = chain.init_state()
    lane_w = engine_mod.recirc_slots(cfg, chunk)
    lane = dead_batch(lane_w, cfg.pmax)
    todo = _chunks(pkts, chunk)
    n_real = len(todo)
    s_up_r, l_up_r, drain = F.pipe_masks(faults, fault_pipe, n_real)
    pad_ones = np.ones(window + 1, bool)
    s_up = np.concatenate([s_up_r, pad_ones])
    l_up = np.concatenate([l_up_r, pad_ones])
    dead_in = dead_batch(chunk, cfg.pmax)
    ring = [dead_batch(chunk + lane_w, cfg.pmax)
            for _ in range(max(window, 1))]
    merged: list = []
    sent: list = []
    tel = dict.fromkeys(TEL_FIELDS, 0)

    for t in range(n_real + window + 1):
        cin = todo[t] if t < n_real else dead_in
        p, b = _alive_stats(cin)
        tel["wire_pkts"] += p
        tel["wire_bytes"] += b
        state, rout = recirc(cfg, state, lane, backend=backend)
        state, out = split(cfg, state, cin, backend=backend)
        out, lane, n_denied = engine_mod.recirc_select(cfg, out, lane_w)
        state = dataclasses.replace(
            state, counters=C.bump(state.counters, "recirc_budget_drops",
                                   n_denied))
        # recirculation-port traffic = what entered the lane this step
        p, b = _alive_stats(lane)
        tel["recirc_pkts"] += p
        tel["recirc_bytes"] += b
        nf_in = jax.tree.map(lambda a, b: jnp.concatenate([a, b]), rout, out)
        if t <= n_real:
            sent.append(nf_in)
        p, b = _alive_stats(nf_in)
        tel["to_server_pkts"] += p
        tel["to_server_bytes"] += b
        killed = nf_in.alive & ~jnp.asarray(s_up[t])
        state = dataclasses.replace(
            state, counters=C.bump(state.counters, "fault_drops",
                                   jnp.sum(killed)))
        srv_in = nf_in.replace(alive=nf_in.alive & jnp.asarray(s_up[t]))
        chain_states, nf_out, dropped, _cycles = chain.run(
            chain_states, srv_in, backend=backend,
            ctx={"lb_up": jnp.asarray(l_up[t])})
        if explicit_drops:
            nf_out = to_explicit_drops(nf_out, dropped)
        nf_out = to_explicit_drops(nf_out, killed & drain)
        if window == 0:
            returning = nf_out
        else:
            slot = t % window
            returning = ring[slot]
            ring[slot] = nf_out
        p, b = _alive_stats(returning)
        tel["from_server_pkts"] += p
        tel["from_server_bytes"] += b
        state, m = merge(cfg, state, returning, backend=backend)
        if t >= window:
            merged.append(m)
        p, b = _alive_stats(m)
        tel["merged_pkts"] += p
        tel["merged_bytes"] += b

    telemetry = LinkTelemetry(**tel)
    return SimResult(
        merged=merged,
        state=state,
        sent_to_server=sent,
        counters=C.as_dict(state.counters),
        srv_bytes=telemetry.srv_bytes,
        wire_bytes=telemetry.wire_bytes,
        ret_bytes=telemetry.merged_bytes,
        telemetry=telemetry,
        nf_counters={k: int(v) for k, v in
                     chain.state_counters(chain_states).items()},
    )


def baseline_roundtrip(chain: Chain, pkts: PacketBatch, backend=None):
    """Non-PayloadPark reference: packets travel whole through the chain
    (on the same backend as the parking run it is compared against)."""
    chain_states = chain.init_state()
    _, out, dropped, cycles = chain.run(chain_states, pkts, backend=backend)
    return out, dropped, cycles
