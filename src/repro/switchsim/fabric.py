"""Fabric-scale sharded simulation: the pipe axis across devices.

One ToR switch is 8 per-port pipes on one device (``engine.run_pipes``,
DESIGN.md §3).  A datacenter fabric is dozens of such switches — hundreds
of pipes over 10⁷+ packets — and pipes share *nothing* (the hardware pipes
share nothing either), so the flat vmapped pipe axis the scenario runner
already batches on (DESIGN.md §8) is embarrassingly shardable.  This
module puts a ``jax.sharding`` mesh under it:

  * ``switch_mesh(devices)`` builds a 1-D mesh over the first ``devices``
    visible devices, axis name ``"switch"`` — each mesh slot simulates an
    equal slice of the fabric's pipes (one or more switches' worth);
  * ``shard_over_switch(run, devices)`` wraps the engine's vmapped
    single-pipe program in ``shard_map``: every input (traces, fault
    masks, drain flags) and every output (states, counters ys, telemetry
    ys) carries the pipe axis leading, so ONE ``PartitionSpec("switch")``
    is the whole contract — no collectives, no replicated outputs, no
    cross-shard traffic of any kind;
  * ``resolve_devices(pipes, devices)`` is the guarded
    fallback-to-replication (``distributed.sharding.divides_axis``, the
    same predicate the model-parallel rules use): when the pipe count
    does not divide the requested device count, or fewer devices are
    visible than requested, the run warns and executes replicated on one
    device — never padded, never crashed.

**Shard-count invariance is the correctness contract**: the same
``ScenarioSpec`` run on 1, 2 or 8 devices yields bit-identical counters,
telemetry and occupancy, because sharding only re-tiles the pipe axis and
every per-pipe scan is reduction-free across pipes (cross-pipe aggregation
happens host-side in int64 after the program returns, exactly as in the
single-device path).  ``tests/test_fabric.py`` pins this on forced host
devices; the engine≡loop oracle holds per shard — ``verify_oracle``
re-runs the host loop on each device's pipe slice independently
(DESIGN.md §12).

CPU-only hosts (CI included) exercise real multi-device sharding via the
forced-host-device recipe: ``distributed.force_host_devices(8)`` before
jax initializes, or ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
in the environment — see ``benchmarks/bench_pipeline.py --host-devices``.

Entry points: ``engine.run_pipes(..., devices=N)`` (the engine owns result
assembly; it resolves the device count through this module and fetches the
shard_mapped program from its compile cache), ``ScenarioSpec(devices=N)``
(a first-class grid axis, part of the compile key), and
``bench_pipeline --devices`` (the scaling sweep, ``BENCH_fabric.json``).

Design notes: DESIGN.md §12 (fabric sharding).
"""
from __future__ import annotations

import warnings

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from repro.distributed.sharding import axis_size, divides_axis

SWITCH_AXIS = "switch"


def fabric_devices() -> int:
    """Devices visible to the fabric (forced host devices included).

    This is the call that initializes the jax backend — anything that
    needs ``distributed.force_host_devices`` must run before it."""
    return len(jax.devices())


def switch_mesh(devices: int) -> Mesh:
    """1-D ``("switch",)`` mesh over the first ``devices`` devices."""
    return jax.make_mesh((devices,), (SWITCH_AXIS,))


def resolve_devices(pipes: int, devices: int | None) -> int:
    """Guarded fallback-to-replication: the device count a ``pipes``-wide
    run will actually shard over.

    Returns ``devices`` when it is usable (>1, visible, and dividing the
    pipe axis — ``distributed.sharding.divides_axis``, the same guard the
    model-parallel rules apply to weight dims); otherwise warns and
    returns 1, i.e. the replicated single-device path.  Shard-count
    invariance makes the fallback safe: results are bit-identical either
    way, only wall-clock changes.
    """
    if devices is None or devices <= 1:
        return 1
    avail = fabric_devices()
    if devices > avail:
        warnings.warn(
            f"fabric: {devices} devices requested but only {avail} "
            f"visible — running replicated on one device.  On CPU, force "
            f"host devices before jax initializes "
            f"(repro.distributed.force_host_devices({devices}) or "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={devices}).",
            stacklevel=2)
        return 1
    if not divides_axis(pipes, devices):
        warnings.warn(
            f"fabric: pipe axis of {pipes} does not divide over "
            f"{devices} devices — falling back to replication "
            f"(single device; results are bit-identical by the "
            f"shard-count-invariance contract).",
            stacklevel=2)
        return 1
    return devices


def shard_over_switch(run, devices: int):
    """Wrap the engine's vmapped pipe program in ``shard_map``.

    ``run`` is ``vmap(_build_scan(...))`` — signature
    ``(traces, server_up, lb_up, drain) -> (state, cstates, ys)`` with the
    pipe axis leading on every input and output leaf.  The whole sharding
    contract is therefore one spec: ``PartitionSpec("switch")`` on axis 0,
    trailing axes replicated.  Each device runs the identical scan over
    its contiguous pipe slice; outputs remain logically global arrays, so
    the engine's host-side finalization (int64 sums, per-pipe slicing, the
    scenario runner's per-scenario regrouping) gathers from the shards
    transparently and is byte-for-byte the single-device code path.

    The caller (``engine._compiled``) jits the returned function and
    caches it keyed on ``devices``, so re-runs never re-trace.
    """
    mesh = switch_mesh(devices)
    assert axis_size(mesh, SWITCH_AXIS) == devices
    spec = PartitionSpec(SWITCH_AXIS)
    # check_rep=False: the body is a pure per-pipe map with no collectives
    # and no replicated outputs, so the replication checker has nothing to
    # prove and only adds tracing overhead on wide fabrics.
    return shard_map(run, mesh=mesh, in_specs=(spec, spec, spec, spec),
                     out_specs=spec, check_rep=False)
