"""Unified result dataclasses for every simulation entry point.

``run_engine`` (single pipe), ``run_pipes`` (vmapped pipes), ``simulate``
/ ``simulate_loop`` (the list-of-chunks oracle view) and the streaming
driver (``switchsim.stream``) each return a different shape of result, but
benches and the scenario runner consume the same facts from all of them:
counters, per-link byte totals, telemetry, peak occupancy and — for the
streaming driver — the tail-latency block.  ``flat_summary`` is that shared
view, exposed as a ``summary()`` method on every result type, so artifact
row-building reads one flat dict instead of hand-picking fields per class.

The dataclasses live here (not in ``engine``/``simulate``) so the streaming
driver can build on the same base without importing the materialized engine;
``engine``/``simulate`` re-export them under their historical names.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.packet import PacketBatch
from repro.core.park import ParkState
from repro.switchsim.telemetry import LinkTelemetry

__all__ = ["EngineResult", "PipesResult", "SimResult", "StreamResult",
           "flat_summary"]


def flat_summary(counters: dict, telemetry: LinkTelemetry | None, *,
                 peak_occupancy: int | None = None,
                 nf_counters: dict | None = None,
                 latency: dict | None = None) -> dict:
    """The shared flat-dict view every ``summary()`` returns.

    Keys: the parking counters by name; ``wire_bytes``/``srv_bytes``/
    ``srv_fwd_bytes``/``ret_bytes`` byte totals; the full per-link
    telemetry as ``tel_<field>``; ``peak_occupancy`` and the NF-private
    counters when present; and the streaming tail-latency block
    (``p50_us``/``p99_us``/``p999_us``/``latency_samples``) when present.
    """
    out = {k: int(v) for k, v in counters.items()}
    if telemetry is not None:
        out["wire_bytes"] = telemetry.wire_bytes
        out["srv_bytes"] = telemetry.srv_bytes
        out["srv_fwd_bytes"] = telemetry.to_server_bytes
        out["ret_bytes"] = telemetry.merged_bytes
        out.update({f"tel_{k}": int(v)
                    for k, v in telemetry.as_dict().items()})
    if peak_occupancy is not None:
        out["peak_occupancy"] = int(peak_occupancy)
    if nf_counters:
        out.update({k: int(v) for k, v in nf_counters.items()})
    if latency:
        out.update({k: latency[k] for k in
                    ("p50_us", "p99_us", "p999_us") if k in latency})
        if "samples" in latency:
            out["latency_samples"] = int(latency["samples"])
    return out


@dataclasses.dataclass
class EngineResult:
    """Result of one engine run (single pipe unless noted).

    ``merged``: (T, chunk, ...) time-major merged output, arrival order
    (recirculated packets re-emerge one step late, in the lane rows that
    lead each chunk).
    ``sent``:   (T, chunk, ...) NF-bound traffic, or None if not collected.
    ``state``:  final ParkState (leading pipe axis when multi-pipe).
    ``wire_bytes``/``srv_bytes``: exact totals, summed host-side in int64.
    ``srv_bytes`` covers BOTH server-link directions; ``srv_fwd_bytes`` is
    the switch->server direction alone — the bottleneck direction when the
    NF chain drops packets (dropped packets never make the return trip).
    ``ret_bytes`` is the return direction the *merge stage put back on the
    wire* (chain survivors at full size): the drop-aware baseline's return
    trip (see ``engine.goodput_gain``).
    ``peak_occupancy``: max live parked slots observed at any step (max
    across pipes when multi-pipe).
    ``telemetry``: exact per-link byte/packet totals (wire in, switch->server,
    server->switch, recirculation port, merged out — DESIGN.md §7); the byte
    fields above are derived views kept for compatibility.
    ``occ_series``: (T+pad,) live parked slots after each step's Merge —
    the time series the fault-injection recovery gates read (DESIGN.md §10).
    ``nf_counters``: NF-private counters from the final chain state (e.g.
    NAT ``nat_stale_hits``), via ``Chain.state_counters``.
    """

    merged: PacketBatch
    sent: PacketBatch | None
    state: ParkState
    counters: dict
    srv_bytes: int
    srv_fwd_bytes: int
    wire_bytes: int
    ret_bytes: int
    peak_occupancy: int
    telemetry: LinkTelemetry
    occ_series: np.ndarray = None
    nf_counters: dict = dataclasses.field(default_factory=dict)

    def summary(self) -> dict:
        return flat_summary(self.counters, self.telemetry,
                            peak_occupancy=self.peak_occupancy,
                            nf_counters=self.nf_counters)


@dataclasses.dataclass
class PipesResult(EngineResult):
    """Aggregated multi-pipe result; per-pipe breakdowns included.

    ``merged``/``sent`` keep the leading pipe axis: (P, T, chunk, ...).
    ``counters`` is the cross-pipe sum; ``per_pipe_counters`` the breakdown.
    """

    per_pipe_counters: list[dict] = dataclasses.field(default_factory=list)
    per_pipe_srv_bytes: list[int] = dataclasses.field(default_factory=list)
    per_pipe_wire_bytes: list[int] = dataclasses.field(default_factory=list)
    # one LinkTelemetry per pipe = per NF server under §6.3.2 steering;
    # feeds repro.hostmodel's per-server PCIe/DMA accounting (DESIGN.md §7)
    per_pipe_telemetry: list[LinkTelemetry] = dataclasses.field(
        default_factory=list)
    # per-pipe peak parked-slot occupancy; the scenario runner regroups a
    # flat vmapped pipe axis back into per-scenario results (DESIGN.md §8)
    # and needs the per-pipe maxima, not only the cross-pipe max
    per_pipe_peak_occupancy: list[int] = dataclasses.field(
        default_factory=list)
    # (P, T+pad) per-pipe occupancy series: server faults hit one pipe, so
    # the recovery gate needs the victim pipe's series, not the aggregate
    per_pipe_occ_series: np.ndarray = None
    per_pipe_nf_counters: list[dict] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class SimResult:
    """The seed list-of-chunks view (``simulate`` / ``simulate_loop``)."""

    merged: list            # list[PacketBatch] in arrival order
    state: ParkState
    sent_to_server: list    # list[PacketBatch] (post-split, pre-NF)
    counters: dict
    srv_bytes: int          # total bytes switch->server (goodput accounting)
    wire_bytes: int         # total bytes generator->switch
    ret_bytes: int          # bytes the merge stage put back on the wire
    telemetry: LinkTelemetry  # exact per-link byte/packet totals (DESIGN.md §7)
    # NF-private counters from the final chain state (Chain.state_counters,
    # e.g. NAT nat_stale_hits) — part of the engine≡loop oracle contract
    nf_counters: dict = dataclasses.field(default_factory=dict)

    def summary(self) -> dict:
        return flat_summary(self.counters, self.telemetry,
                            nf_counters=self.nf_counters)


@dataclasses.dataclass
class StreamResult:
    """Result of a streaming run (``switchsim.stream.run_stream``).

    Constant-memory by construction: no merged/sent traffic is retained —
    what survives is the final switch state, exact counters/telemetry
    (bit-identical to the materialized engine over the same steps, the
    segment-replay oracle's contract), the reservoir-sampled sojourn-time
    distribution (``latency``: p50/p99/p999 in µs plus sample counts) and
    per-segment occupancy summaries (``occ_segments``: one dict per segment
    with ``start``/``steps``/``min``/``mean``/``max``/``last``) standing in
    for the full occupancy series a materialized run would keep.
    """

    state: ParkState
    counters: dict
    telemetry: LinkTelemetry
    nf_counters: dict
    peak_occupancy: int
    latency: dict
    occ_segments: list[dict]
    steps: int
    segments: int
    segment_len: int

    @property
    def wire_bytes(self) -> int:
        return self.telemetry.wire_bytes

    @property
    def srv_bytes(self) -> int:
        return self.telemetry.srv_bytes

    @property
    def srv_fwd_bytes(self) -> int:
        return self.telemetry.to_server_bytes

    @property
    def ret_bytes(self) -> int:
        return self.telemetry.merged_bytes

    def summary(self) -> dict:
        return flat_summary(self.counters, self.telemetry,
                            peak_occupancy=self.peak_occupancy,
                            nf_counters=self.nf_counters,
                            latency=self.latency)
