"""Analytic link / PCIe / NF-server performance model.

Calibrated against the paper's own measurements so the benchmark suite can
reproduce its figures quantitatively:

  * Goodput is measured "from the RMT switch's perspective ... the packet
    header as the unit of useful information" (§6.1): 42 bytes per delivered
    packet.  10 Mpps == 3.36 Gbps goodput.
  * PCIe/NIC model (from §6.2.2 + Neugebauer et al. pcie-bench): the NF
    server's NIC is limited by BOTH an effective byte rate (~50 Gbps on
    PCIe Gen3 x8) AND a DMA transaction rate of ~31.5 Mpps — the paper's own
    numbers: "26 Gbps accommodates 31 million 103 byte packets" and "a modern
    NIC with DPDK driver cannot operate at 40 Gbps for packets smaller than
    170 bytes".
  * NF server compute: pps_max = cores * freq / cycles_per_packet, with the
    per-chain cycle costs from nf/*.py (§6.3.3 NF-Light/Medium/Heavy = 50/
    300/570 cycles).
  * Latency: fixed base (wire + switch + DPDK) plus an M/D/1 queueing term on
    the bottleneck resource; the paper's Fig. 7 latency cliff at link
    saturation emerges from the queueing term.
  * Healthy operation = drop rate < 0.1 % (§6.1); peak goodput is the largest
    send rate that stays healthy AND premature-eviction free (§6.3.1).

All rates are bits/second; sizes are bytes.
"""
from __future__ import annotations

import dataclasses

from repro.core.packet import HDR_BYTES, PP_HDR_BYTES

GOODPUT_BYTES = HDR_BYTES  # 42-byte header = useful information (§6.1)


@dataclasses.dataclass(frozen=True)
class ServerModel:
    link_gbps: float = 40.0          # switch <-> NF server NIC
    pcie_gbps: float = 50.0          # effective PCIe Gen3 x8 byte rate
    pcie_mpps: float = 31.5          # DMA transaction rate cap
    cpu_ghz: float = 2.3             # Xeon E7-4870 v2 (§6.1)
    cores_per_nf: int = 1            # OpenNetVM pins each NF to one core
    overhead_cycles: float = 60.0    # DPDK rx/tx + framework per packet
    framework_mpps: float = 17.5     # ONVM manager rx/tx core packet cap
    base_latency_us: float = 28.0    # wire + switch + DPDK baseline (Fig. 7)
    recirc_latency_us: float = 0.05  # one extra pipeline traversal (§6.2.5)


@dataclasses.dataclass(frozen=True)
class TrafficDigest:
    """Per-workload aggregates the analytic model needs.

    ``mean_wire_bytes``: average bytes/packet on the generator->switch link.
    ``mean_srv_bytes``:  average bytes/packet on the switch->server link
                          (equals wire bytes in baseline; reduced by parking).
    ``park_fraction``:   fraction of packets parked (ENB=1).
    ``recirc_per_pkt``:  expected recirculation passes per packet (§6.2.5);
                          0 without recirculation.  Feeds the per-packet
                          expected-passes latency term in ``evaluate``.
    """

    mean_wire_bytes: float
    mean_srv_bytes: float
    park_fraction: float
    recirc_per_pkt: float = 0.0


def digest(sizes, probs, park_bytes: int, min_park_len: int,
           parking: bool, pass_bytes: int | None = None) -> TrafficDigest:
    """Compute the per-packet byte averages for a size distribution.

    ``pass_bytes`` models recirculation (§6.2.5): one pipeline traversal
    parks at most ``pass_bytes``; a packet whose parked share exceeds it
    takes one recirculation pass to fill the remaining row width (the
    engine's single-recirculation model, DESIGN.md §6)."""
    mean_wire = float(sum(s * p for s, p in zip(sizes, probs)))
    if not parking:
        return TrafficDigest(mean_wire, mean_wire, 0.0)
    srv = 0.0
    park_frac = 0.0
    recirc = 0.0
    for s, p in zip(sizes, probs):
        payload = s - HDR_BYTES
        if payload >= min_park_len:
            parked = min(payload, park_bytes)
            srv += p * (s - parked + PP_HDR_BYTES)
            park_frac += p
            if pass_bytes is not None and parked > pass_bytes:
                recirc += p
        else:
            srv += p * (s + PP_HDR_BYTES)
    return TrafficDigest(mean_wire, srv, park_frac, recirc)


def measured_digest(n_pkts: int, wire_bytes: int, srv_fwd_bytes: int,
                    park_fraction: float,
                    recirc_per_pkt: float = 0.0) -> TrafficDigest:
    """TrafficDigest from the scanned engine's measured byte totals.

    ``srv_fwd_bytes`` is the engine's switch->server direction alone
    (``EngineResult.srv_fwd_bytes``).  That is the bottleneck direction:
    every offered packet crosses it, while the return direction carries only
    NF-chain survivors — averaging both directions would understate the
    forward load whenever the chain drops packets.  This closes the loop
    between the stateful simulation and the analytic model: feed the
    measured digest to ``evaluate``/``peak_goodput`` to predict rates for
    the traffic actually simulated, hash skew, eviction losses and all.
    ``recirc_per_pkt`` is the measured rate ``counters['recirculations'] /
    packets`` when the engine ran with the recirculation lane.
    """
    n = max(n_pkts, 1)
    return TrafficDigest(
        mean_wire_bytes=wire_bytes / n,
        mean_srv_bytes=srv_fwd_bytes / n,
        park_fraction=park_fraction,
        recirc_per_pkt=recirc_per_pkt,
    )


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    send_gbps: float
    pps: float
    goodput_gbps: float
    latency_us: float
    drop_rate: float
    pcie_gbps_used: float
    bottleneck: str
    util: float


def evaluate(m: ServerModel, d: TrafficDigest, nf_cycles,
             send_gbps: float) -> OperatingPoint:
    """Evaluate one send rate; drops appear when any resource saturates.

    ``nf_cycles``: per-NF per-packet CPU cycle costs.  OpenNetVM pins each NF
    to a core, so the chain's CPU cap is the slowest single NF (§6.1)."""
    if isinstance(nf_cycles, (int, float)):
        nf_cycles = [float(nf_cycles)]
    pps_offered = send_gbps * 1e9 / (d.mean_wire_bytes * 8)

    # Resource capacities in packets/second.
    slowest_nf = max(nf_cycles) + m.overhead_cycles
    cap = {
        "link": m.link_gbps * 1e9 / (d.mean_srv_bytes * 8),
        "pcie_bytes": m.pcie_gbps * 1e9 / (d.mean_srv_bytes * 8),
        "pcie_txn": m.pcie_mpps * 1e6,
        "cpu": m.cores_per_nf * m.cpu_ghz * 1e9 / slowest_nf,
        "framework": m.framework_mpps * 1e6,
    }
    bottleneck = min(cap, key=cap.get)
    pps_cap = cap[bottleneck]

    pps_delivered = min(pps_offered, pps_cap)
    drop_rate = max(0.0, 1.0 - pps_delivered / max(pps_offered, 1e-9))
    goodput = pps_delivered * GOODPUT_BYTES * 8 / 1e9

    # M/D/1 queueing on the bottleneck; saturate gracefully near rho=1.
    rho = min(pps_offered / pps_cap, 0.999999)
    service_us = 1e6 / pps_cap
    queue_us = rho / (2.0 * (1.0 - rho)) * service_us
    queue_us = min(queue_us, 2000.0)  # queue bound ~ buffer-limited
    latency = m.base_latency_us + queue_us
    # Recirculation: each pass is one extra traversal of the ingress
    # pipeline.  Expected-passes term (analytic from digest(), or measured
    # from the engine's recirculations counter) replaces the old flat
    # constant that charged every workload the same penalty.
    latency += m.recirc_latency_us * d.recirc_per_pkt

    pcie_used = pps_delivered * d.mean_srv_bytes * 8 / 1e9
    return OperatingPoint(send_gbps, pps_delivered, goodput, latency,
                          drop_rate, pcie_used, bottleneck, rho)


def peak_goodput(m: ServerModel, d: TrafficDigest, nf_cycles,
                 table_capacity: int = 0, max_exp: int = 1,
                 nf_latency_us: float = 30.0, parking: bool = False,
                 healthy_drop: float = 0.001) -> OperatingPoint:
    """Largest send rate with drop rate < 0.1 % and no premature evictions.

    The premature-eviction constraint (§4, §6.3.1): a parked payload survives
    ``max_exp`` full wraps of the circular table index, i.e. for
    ``max_exp * M / pps_parked`` seconds; it must exceed the split->merge
    time-delta (~NF latency):  M * EXP >= pps_parked * T_delta.
    """
    lo, hi = 0.01, 200.0
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        op = evaluate(m, d, nf_cycles, mid)
        healthy = op.drop_rate <= healthy_drop
        if parking and table_capacity > 0 and d.park_fraction > 0:
            pps_parked = op.pps * d.park_fraction
            survive_us = max_exp * table_capacity / pps_parked * 1e6
            healthy &= survive_us >= nf_latency_us
        if healthy:
            lo = mid
        else:
            hi = mid
    return evaluate(m, d, nf_cycles, lo)


@dataclasses.dataclass(frozen=True)
class HostOperatingPoint:
    """An ``OperatingPoint`` augmented with the host model's view
    (DESIGN.md §7): predicted PCIe load per direction with TLP/descriptor
    overheads, bus utilization, and the server-side pps bound from the
    per-server cycle budget."""

    op: OperatingPoint
    pcie_rx_gbps: float     # switch->server bus load incl. DMA overheads
    pcie_tx_gbps: float     # server->switch bus load incl. DMA overheads
    pcie_util: float        # busiest direction / effective link rate
    server_pps_cap: float   # cycle-budget + PCIe + DMA-txn bound
    server_bottleneck: str  # 'cpu' | 'pcie_rx' | 'pcie_tx' | 'dma_txn'


def evaluate_host(m: ServerModel, d: TrafficDigest, nf_cycles,
                  send_gbps: float, host=None) -> HostOperatingPoint:
    """``evaluate`` plus the host model: PCIe bus load and server-bound
    throughput for the same digest (DESIGN.md §7).

    The analytic digest carries one server-link mean (``mean_srv_bytes``),
    used for both directions — exact without chain drops, an upper bound
    on the return direction with them.  The delivered pps is additionally
    clamped by the host model's cycle-budget bound, which may be tighter
    than ``ServerModel``'s flat caps for byte-heavy traffic.
    """
    from repro.hostmodel.server import HostModel, server_bound_pps
    host = host if host is not None else HostModel()
    op = evaluate(m, d, nf_cycles, send_gbps)
    bound = server_bound_pps(host, nf_cycles,
                             d.mean_srv_bytes, d.mean_srv_bytes)
    pps = min(op.pps, bound.pps)
    bus_per_pkt = host.link.mean_bus_bytes(d.mean_srv_bytes)
    rx_gbps = pps * bus_per_pkt * 8 / 1e9
    tx_gbps = rx_gbps  # symmetric under the one-mean digest
    util = max(rx_gbps, tx_gbps) / host.link.effective_gbps
    return HostOperatingPoint(
        op=op, pcie_rx_gbps=rx_gbps, pcie_tx_gbps=tx_gbps, pcie_util=util,
        server_pps_cap=bound.pps, server_bottleneck=bound.bottleneck)


def scale_pipes(op: OperatingPoint, pipes: int) -> OperatingPoint:
    """Aggregate operating point for ``pipes`` independent per-port pipes.

    The paper services up to 8 NF servers from one ToR switch, one pipe per
    server-facing port (§6.3.2); pipes share no switch state and each feeds
    its own server/link, so throughput-like quantities scale linearly while
    per-packet latency, drop rate and utilization are unchanged.
    """
    return dataclasses.replace(
        op,
        send_gbps=op.send_gbps * pipes,
        pps=op.pps * pipes,
        goodput_gbps=op.goodput_gbps * pipes,
        pcie_gbps_used=op.pcie_gbps_used * pipes,
    )
