"""Device-resident, multi-pipe PayloadPark simulation engine.

The seed ``simulate()`` drove one ``ParkState`` through a host-side Python
chunk loop with per-chunk ``int(jnp.sum(...))`` syncs — every chunk paid a
dispatch + device->host round trip, and only one pipe existed.  This module
compiles the whole split -> NF-chain -> merge timeline into ONE XLA program:

  * ``lax.scan`` over time steps.  The carry holds ``(ParkState, NF-chain
    states, in-flight ring buffer, step index)``; the per-step ys carry the
    merged chunk plus int32 per-link byte/packet tallies (wire in,
    switch->server, server->switch, recirculation port, merged out —
    ``switchsim.telemetry.LinkTelemetry``, DESIGN.md §7), so accounting
    lives on-device and is aggregated once at the end.
  * The in-flight window — the paper's split->merge time delta (~30 us, §4)
    — is a ``window``-deep ring of packet chunks indexed by ``t % window``
    with ``dynamic_index_in_dim`` / ``dynamic_update_index_in_dim``; chunk
    ``t`` is split at step ``t`` and its NF output merges at ``t + window``,
    exactly the seed loop's timeline.
  * ``vmap`` over a leading pipe axis replicates the engine per ingress
    shard — one ``ParkState`` per pipe, mirroring the paper's per-port pipes
    that let one ToR switch service up to 8 NF servers (§6.3.2).  Pipes
    share nothing (the hardware pipes share nothing either); cross-pipe
    goodput is aggregated host-side after the single device program returns.
  * The recirculation lane (``cfg.recirculation``, paper §6.2.5, DESIGN.md
    §6) is a second ring in the carry: Split outputs that want another
    pipeline pass (partial park with row width remaining, or an
    occupied-slot skip) detour into a ``recirc_slots``-wide lane instead of
    forwarding, re-enter through ``core.park.recirc_fn`` at the next step,
    and only then travel to the NF server.  Lane width is the
    recirculation port's bandwidth share (``recirc_frac`` of the per-step
    chunk); candidates beyond it forward as-is and are counted
    ``recirc_budget_drops``.

Semantics with recirculation off are bit-identical to the seed loop
(``simulate.simulate_loop``): padding chunks are all-dead (``alive=False``)
and every Split/Merge/NF state update is predicated on ``alive``, so the
padded steps are exact no-ops on the switch state.  With recirculation on,
``simulate_loop`` mirrors the lane host-side and stays the executable
oracle.  ``tests/test_engine.py`` / ``tests/test_recirc.py`` assert
wire-level equality for both modes.

Design notes: DESIGN.md §3 (engine), §6 (recirculation).
"""
from __future__ import annotations

import dataclasses
import math
from functools import lru_cache
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.backend import coerce_backend
from repro.core import counters as C
from repro.core.packet import PacketBatch, gather_rows
from repro.core.park import (ParkConfig, init_state, merge_fn,
                             occupancy, recirc_fn, split_fn)
from repro.nf.chain import Chain, to_explicit_drops
from repro.switchsim import faults as F
from repro.switchsim.results import EngineResult, PipesResult
from repro.switchsim.telemetry import (TEL_FIELDS, LinkTelemetry,
                                       sum_telemetry)
from repro.traffic import stream as stream_mod

__all__ = [
    "EngineResult", "PipesResult", "run_engine", "run_pipes",
    "goodput_gain", "goodput_gain_from_telemetry", "recirc_slots",
    "recirc_select", "scan_step", "init_carry",
]


def _alive_bytes(p: PacketBatch) -> jax.Array:
    return jnp.sum(jnp.where(p.alive, p.pkt_len(), 0))


def _alive_pkts(p: PacketBatch) -> jax.Array:
    return jnp.sum(p.alive.astype(jnp.int32))


def recirc_slots(cfg: ParkConfig, chunk: int) -> int:
    """Recirculation-lane width: the per-step packet budget of the
    recirculation port, ``floor(recirc_frac * chunk)`` — the port owns a
    fixed share of the pipe's per-step capacity (paper §6.2.5).  0 (either
    recirculation off, or a share smaller than one packet) disables the
    lane entirely; Split then parks single-pass only."""
    if not cfg.recirculation:
        return 0
    # epsilon guards binary-representation error (0.29 * 100 == 28.999...),
    # so exact fractional shares floor to the intended slot count
    return math.floor(cfg.recirc_frac * chunk + 1e-9)


def recirc_select(cfg: ParkConfig, out: PacketBatch, budget: int):
    """Admit up to ``budget`` recirculation candidates from a Split output.

    Candidates (DESIGN.md §6):
      * continuation — parked (ENB=1) with payload remaining: the row still
        has ``park_bytes - pass_bytes`` spare width for a second pass;
      * retry — Split disabled on an occupied slot (ENB=0 with an eligible
        payload): a second pass re-attempts the claim.

    Admitted packets (first ``budget`` in arrival order) detour into the
    lane instead of forwarding — one extra step of latency; denied
    candidates forward as-is (the paper's ENB=0 fallback) and are counted
    by the caller via the returned ``n_denied``.

    Returns ``(forwarded, lane, n_denied)`` where ``lane`` is a
    ``budget``-row PacketBatch (dead rows beyond the admitted count).
    """
    cont = out.alive & out.pp_valid & (out.pp_enb == 1) & (out.payload_len > 0)
    retry = out.alive & out.pp_valid & (out.pp_enb == 0) & \
        (out.payload_len >= cfg.min_park_len)
    cand = cont | retry
    pos = jnp.cumsum(cand) - 1
    admit = cand & (pos < budget)
    b = out.alive.shape[0]
    # Invert: lane_src[pos] = row index; empty lane slots gather a dead row.
    dest = jnp.where(admit, pos, budget)
    lane_src = jnp.full((budget,), b, jnp.int32)
    lane_src = lane_src.at[dest].set(jnp.arange(b, dtype=jnp.int32),
                                     mode="drop")
    lane = gather_rows(out, lane_src)
    forwarded = out.replace(alive=out.alive & ~admit)
    return forwarded, lane, jnp.sum(cand & ~admit)


def _cat_rows(a: PacketBatch, b: PacketBatch) -> PacketBatch:
    return jax.tree.map(lambda x, y: jnp.concatenate([x, y], axis=0), a, b)


def init_carry(cfg: ParkConfig, chain: Chain, chunk_like: PacketBatch,
               window: int, recirc: int):
    """Fresh scan carry (ParkState, NF-chain states, in-flight ring,
    recirculation lane, step index) for a pipe whose per-step chunks have
    ``chunk_like``'s (chunk, ...) geometry.  Shared by the materialized
    scan and the streaming driver — the streaming segment program threads
    exactly this carry across segments (donated, DESIGN.md §13)."""
    # All-dead chunks are all-zeros in every field (alive=False == 0),
    # so a zeros ring is a ring of dead chunks.  With a recirculation
    # lane the NF-bound chunks are ``recirc`` rows wider.
    ring = jax.tree.map(
        lambda a: jnp.zeros(
            (max(window, 1), a.shape[0] + recirc) + a.shape[1:], a.dtype),
        chunk_like)
    lane0 = jax.tree.map(
        lambda a: jnp.zeros((recirc,) + a.shape[1:], a.dtype),
        chunk_like) if recirc else ()
    return (init_state(cfg), chain.init_state(), ring, lane0,
            jnp.zeros((), jnp.int32))


def scan_step(cfg: ParkConfig, chain: Chain, window: int,
              explicit_drops: bool, backend, collect_sent: bool,
              recirc: int):
    """The per-step body both engines scan: carry, (chunk, masks), drain ->
    carry, telemetry ys.  Factored out of the materialized scan so the
    streaming segment program (``switchsim.stream``) runs the IDENTICAL
    step — segment-replay bit-exactness holds by construction, not by
    parallel maintenance of two bodies.

    ``recirc`` is the recirculation-lane width (0 = lane off; the step body
    is then exactly the seed timeline, keeping the bit-exactness oracle).

    Fault injection (DESIGN.md §10) rides the scan as extra xs — per-step
    ``server_up``/``lb_up`` bools — plus a traced ``drain`` scalar.  With
    all-True masks every fault operation is a bit-exact no-op, so the SAME
    compiled program serves healthy and faulted runs; fault timing is data.
    """

    def step(carry, xs, drain):
        state, cstates, ring, lane, t = carry
        cin, s_up, l_up = xs
        wire_b = _alive_bytes(cin)
        wire_p = _alive_pkts(cin)
        if recirc:
            # Second pass for packets re-injected at the previous step
            # (their wire bytes were paid on first arrival).
            state, rout = recirc_fn(cfg, state, lane, backend=backend)
        state, out = split_fn(cfg, state, cin, backend=backend)
        if recirc:
            out, lane, n_denied = recirc_select(cfg, out, recirc)
            state = dataclasses.replace(
                state, counters=C.bump(state.counters,
                                       "recirc_budget_drops", n_denied))
            # recirculation-port traffic = what enters the lane this step
            rec_b, rec_p = _alive_bytes(lane), _alive_pkts(lane)
            nf_in = _cat_rows(rout, out)
        else:
            rec_b = rec_p = jnp.zeros((), jnp.int32)
            nf_in = out
        # to_server telemetry is tallied on nf_in BEFORE the kill: the
        # switch still transmits to a dead server (the link is up, the
        # host is not), so the forward link carries the bytes either way
        to_srv_p, to_srv_b = _alive_pkts(nf_in), _alive_bytes(nf_in)
        # Server fault (DESIGN.md §10): packets forwarded while this
        # pipe's server is down are lost at send time.  The chain still
        # runs on the step (dead rows are no-ops on NF state — a down
        # server processes nothing).
        killed = nf_in.alive & ~s_up
        state = dataclasses.replace(
            state, counters=C.bump(state.counters, "fault_drops",
                                   jnp.sum(killed)))
        srv_in = nf_in.replace(alive=nf_in.alive & s_up)
        cstates, nf_out, dropped, _cycles = chain.run(
            cstates, srv_in, backend=backend, ctx={"lb_up": l_up})
        if explicit_drops:
            nf_out = to_explicit_drops(nf_out, dropped)
        # Drain-vs-drop rule: with drain, the failover agent turns each
        # killed packet's parked payload into an OP=drop notification on
        # the return path (the §6.2.4 machinery frees the slot at
        # Merge); without it the slots leak until expiry-based eviction.
        nf_out = to_explicit_drops(nf_out, killed & drain)
        if window == 0:
            returning = nf_out
        else:
            slot = jnp.mod(t, window)
            returning = jax.tree.map(
                lambda r: jax.lax.dynamic_index_in_dim(
                    r, slot, axis=0, keepdims=False), ring)
            ring = jax.tree.map(
                lambda r, v: jax.lax.dynamic_update_index_in_dim(
                    r, v, slot, axis=0), ring, nf_out)
        state, m = merge_fn(cfg, state, returning, backend=backend)
        # Per-link telemetry ys, keyed by LinkTelemetry field names
        # (DESIGN.md §7); summed host-side in int64 by _finalize.
        ys = dict(
            merged=m, occ=occupancy(state),
            wire_pkts=wire_p, wire_bytes=wire_b,
            to_server_pkts=to_srv_p,
            to_server_bytes=to_srv_b,
            from_server_pkts=_alive_pkts(returning),
            from_server_bytes=_alive_bytes(returning),
            recirc_pkts=rec_p, recirc_bytes=rec_b,
            merged_pkts=_alive_pkts(m), merged_bytes=_alive_bytes(m),
        )
        if collect_sent:
            ys["sent"] = nf_in
        return (state, cstates, ring, lane, t + 1), ys

    return step


def _build_scan(cfg: ParkConfig, chain: Chain, window: int,
                explicit_drops: bool, backend, collect_sent: bool,
                recirc: int):
    """Single-pipe scan body: trace (T+pad, chunk, ...) -> ys + final."""
    step = scan_step(cfg, chain, window, explicit_drops, backend,
                     collect_sent, recirc)

    def run(trace: PacketBatch, server_up: jax.Array, lb_up: jax.Array,
            drain: jax.Array):
        chunk_like = jax.tree.map(lambda a: a[0], trace)
        carry0 = init_carry(cfg, chain, chunk_like, window, recirc)
        (state, cstates, _, _, _), ys = jax.lax.scan(
            lambda c, xs: step(c, xs, drain), carry0,
            (trace, server_up, lb_up))
        return state, cstates, ys

    return run


@lru_cache(maxsize=None)
def _compiled(cfg: ParkConfig, chain: Chain, window: int,
              explicit_drops: bool, backend, collect_sent: bool,
              pipes: bool, recirc: int, devices: int = 1):
    # ``backend`` is a concrete (platform-resolved) BackendConfig, so the
    # cache key — like the jit static args — specializes per backend.
    # ``devices`` > 1 shard_maps the vmapped pipe axis over the fabric
    # mesh (switchsim.fabric, DESIGN.md §12); the caller has already
    # resolved it through ``fabric.resolve_devices``.
    run = _build_scan(cfg, chain, window, explicit_drops, backend,
                      collect_sent, recirc)
    if pipes:
        run = jax.vmap(run)
        if devices > 1:
            from repro.switchsim.fabric import shard_over_switch
            run = shard_over_switch(run, devices)
    return jax.jit(run)


def _pad_trace(trace: PacketBatch, window: int, axis: int = 0) -> PacketBatch:
    """Append ``window`` all-dead chunks (zeros) along the time axis so the
    last in-flight chunks drain through the scan."""
    if window == 0:
        return trace

    def pad(a):
        shape = list(a.shape)
        shape[axis] = window
        return jnp.concatenate([a, jnp.zeros(shape, a.dtype)], axis=axis)

    return jax.tree.map(pad, trace)


def _sum_telemetry(ys: dict) -> LinkTelemetry:
    """Total LinkTelemetry across every remaining axis (time, and pipes
    when present), summed in int64 so totals are exact."""
    return LinkTelemetry(**{
        name: int(np.asarray(ys[name], np.int64).sum())
        for name in TEL_FIELDS})


def _per_pipe_telemetry(ys: dict) -> list[LinkTelemetry]:
    """One LinkTelemetry per pipe: sum (P, T) ys over the time axis only."""
    sums = {name: np.asarray(ys[name], np.int64).sum(axis=-1)
            for name in TEL_FIELDS}
    n_pipes = next(iter(sums.values())).shape[0]
    return [LinkTelemetry(**{name: int(sums[name][p]) for name in TEL_FIELDS})
            for p in range(n_pipes)]


def _finalize(ys: dict, window: int, collect_sent: bool, time_axis: int):
    """Slice the warm-up/drain steps off the merged/sent ys."""
    t_pad = ys["wire_bytes"].shape[-1]
    t_real = t_pad - window

    def slice_time(a, start, stop):
        idx = [slice(None)] * a.ndim
        idx[time_axis] = slice(start, stop)
        return a[tuple(idx)]

    merged = jax.tree.map(
        lambda a: slice_time(a, window, t_pad), ys["merged"])
    sent = None
    if collect_sent:
        sent = jax.tree.map(lambda a: slice_time(a, 0, t_real), ys["sent"])
    occ = np.asarray(ys["occ"], np.int64).max() if ys["occ"].size else 0
    return merged, sent, int(occ)


def _pad_masks(fa: F.FaultArrays, pad: int):
    """Extend the fault masks with all-True columns over the drain/warm-up
    padding steps — faults live within the offered trace (faults.py)."""
    ones = np.ones((fa.pipes, pad), bool)
    return (jnp.asarray(np.concatenate([fa.server_up, ones], axis=1)),
            jnp.asarray(np.concatenate([fa.lb_up, ones], axis=1)),
            jnp.asarray(fa.drain))


def _nf_counters(chain: Chain, cstates) -> dict[str, int]:
    return {k: int(v) for k, v in chain.state_counters(cstates).items()}


def run_engine(
    cfg: ParkConfig,
    chain: Chain,
    trace,
    window: int = 1,
    explicit_drops: bool = False,
    backend=None,
    collect_sent: bool = False,
    faults=None,
) -> EngineResult:
    """Run one pipe over a trace source under one jit.

    ``trace`` is a ``traffic.stream.TraceSource`` — or a time-major
    (T, chunk, ...) ``PacketBatch``, which is the trivial one-shot source
    (``MaterializedSource``) and is coerced through it.  This entry point
    materializes the whole source; ``switchsim.stream.run_stream`` is the
    constant-memory path for sources too long to materialize.

    Bit-identical to ``simulate.simulate_loop`` on the same trace (the seed
    Python loop), but the whole timeline is a single compiled program.
    With ``cfg.recirculation`` the trace is padded one extra step so the
    recirculation lane drains, and NF-bound chunks gain ``recirc_slots``
    leading lane rows.  ``backend`` selects the hot-path primitive
    implementations (``repro.backend``, DESIGN.md §9) for Split/Merge,
    header validation and the NF chain alike.  ``faults`` is a
    ``switchsim.faults.FaultSpec`` (or pre-lowered ``FaultArrays``);
    None/NO_FAULT runs healthy through the same compiled program.
    """
    backend = coerce_backend(backend)
    trace = stream_mod.as_source(trace).materialize()
    chunk = jax.tree.leaves(trace)[0].shape[1]
    steps = jax.tree.leaves(trace)[0].shape[0]
    lane = recirc_slots(cfg, chunk)
    pad = window + (1 if lane else 0)
    fa = F.resolve(faults, pipes=1, steps=steps)
    s_up, l_up, drain = _pad_masks(fa, pad)
    trace = _pad_trace(trace, pad, axis=0)
    fn = _compiled(cfg, chain, window, explicit_drops, backend,
                   collect_sent, pipes=False, recirc=lane)
    state, cstates, ys = fn(trace, s_up[0], l_up[0], drain[0])
    merged, sent, occ = _finalize(ys, window, collect_sent, time_axis=0)
    tel = _sum_telemetry(ys)
    return EngineResult(
        merged=merged, sent=sent, state=state,
        counters=C.as_dict(state.counters),
        srv_bytes=tel.srv_bytes, srv_fwd_bytes=tel.to_server_bytes,
        wire_bytes=tel.wire_bytes, ret_bytes=tel.merged_bytes,
        peak_occupancy=occ, telemetry=tel,
        occ_series=np.asarray(ys["occ"], np.int64),
        nf_counters=_nf_counters(chain, cstates),
    )


def _as_pipe_traces(traces) -> PacketBatch:
    """Coerce ``run_pipes``'s accepted trace spellings to (P, T, chunk, ...):
    a pre-stacked PacketBatch passes through; a TraceSource becomes one
    pipe; a sequence of per-pipe sources is materialized and stacked."""
    if isinstance(traces, PacketBatch):
        return traces
    if isinstance(traces, stream_mod.TraceSource):
        traces = [traces]
    if isinstance(traces, (list, tuple)):
        mats = [stream_mod.as_source(t).materialize() for t in traces]
        return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *mats)
    raise TypeError(
        f"traces must be a PacketBatch, a TraceSource or a sequence of "
        f"TraceSources; got {type(traces).__name__}")


def run_pipes(
    cfg: ParkConfig,
    chain: Chain,
    traces,
    window: int = 1,
    explicit_drops: bool = False,
    backend=None,
    collect_sent: bool = False,
    faults=None,
    devices: int = 1,
) -> PipesResult:
    """Run P independent pipes over per-pipe trace sources, vmapped.

    ``traces`` is a sequence of per-pipe ``traffic.stream.TraceSource``s
    (equal geometry, stacked after materialization), a single source
    (one pipe), or the pre-stacked (P, T, chunk, ...) ``PacketBatch`` the
    sources materialize to.

    Each pipe owns a fresh ``ParkState`` and NF-chain state (the paper's
    per-port pipes share nothing, §6.3.2); one compiled program drives all
    of them.  Byte totals and counters are aggregated across pipes.
    ``backend``/``faults`` behave exactly as in ``run_engine``
    (``FaultArrays`` here may carry per-pipe masks stacked by the scenario
    runner across batched scenario points).

    ``devices`` > 1 shards the pipe axis over that many devices via
    ``switchsim.fabric`` (mesh axis ``"switch"``, DESIGN.md §12).  Results
    are bit-identical for any device count (shard-count invariance); the
    request falls back to 1 with a warning when the pipe count does not
    divide it or fewer devices are visible.
    """
    backend = coerce_backend(backend)
    traces = _as_pipe_traces(traces)
    n_pipes = jax.tree.leaves(traces)[0].shape[0]
    chunk = jax.tree.leaves(traces)[0].shape[2]
    steps = jax.tree.leaves(traces)[0].shape[1]
    lane = recirc_slots(cfg, chunk)
    pad = window + (1 if lane else 0)
    fa = F.resolve(faults, pipes=n_pipes, steps=steps)
    s_up, l_up, drain = _pad_masks(fa, pad)
    traces = _pad_trace(traces, pad, axis=1)
    if devices != 1:
        from repro.switchsim import fabric
        devices = fabric.resolve_devices(n_pipes, devices)
    fn = _compiled(cfg, chain, window, explicit_drops, backend,
                   collect_sent, pipes=True, recirc=lane, devices=devices)
    state, cstates, ys = fn(traces, s_up, l_up, drain)
    merged, sent, occ = _finalize(ys, window, collect_sent, time_axis=1)
    per_tel = _per_pipe_telemetry(ys)
    tel = sum_telemetry(per_tel)
    occ_pp = np.asarray(ys["occ"], np.int64)  # (P, T+pad)
    per_occ = [int(v) for v in occ_pp.max(axis=-1)] if occ_pp.size \
        else [0] * n_pipes
    ctr = np.asarray(state.counters, np.int64)  # (P, C.NUM)
    agg = dict(zip(C.NAMES, (int(v) for v in ctr.sum(axis=0))))
    per_pipe = [dict(zip(C.NAMES, (int(v) for v in ctr[p])))
                for p in range(n_pipes)]
    per_nf = [_nf_counters(chain, jax.tree.map(lambda a: a[p], cstates))
              for p in range(n_pipes)]
    nf_agg = {k: sum(d[k] for d in per_nf)
              for k in (per_nf[0] if per_nf else {})}
    return PipesResult(
        merged=merged, sent=sent, state=state,
        counters=agg, srv_bytes=tel.srv_bytes,
        srv_fwd_bytes=tel.to_server_bytes, wire_bytes=tel.wire_bytes,
        ret_bytes=tel.merged_bytes, peak_occupancy=occ, telemetry=tel,
        occ_series=occ_pp, nf_counters=nf_agg,
        per_pipe_counters=per_pipe,
        per_pipe_srv_bytes=[t.srv_bytes for t in per_tel],
        per_pipe_wire_bytes=[t.wire_bytes for t in per_tel],
        per_pipe_telemetry=per_tel,
        per_pipe_peak_occupancy=per_occ,
        per_pipe_occ_series=occ_pp,
        per_pipe_nf_counters=per_nf,
    )


def goodput_gain(res: EngineResult) -> dict[str, Any]:
    """Server-link byte saving vs the non-parking baseline.

    Parking carries headers + un-parked tails + the 7-byte PP header
    (``srv_bytes``, both directions as measured).  Two baselines:

    * **drop-aware** (the headline ``goodput_gain``): forward trip carries
      every offered packet whole (``wire_bytes``); the return trip only the
      NF-chain survivors at full size (``ret_bytes``).  A no-parking
      deployment of the same chain drops the same packets server-side, so
      this is the byte count it would actually put on the link.  (Exact up
      to premature-eviction losses, which kill packets the baseline would
      have returned; in healthy operation those are zero.)
    * **naive** (``*_naive``, the seed formula): ``2 * wire_bytes`` — it
      pretends the chain-dropped packets made the return trip too, padding
      the baseline with bytes no deployment would carry and skewing the
      gain whenever the chain drops (e.g. NAT overflow, firewall rules).

    Positive saving = goodput gain on the switch<->server link (the
    paper's §6.1 metric, byte form).
    """
    return _gain_from_bytes(res.wire_bytes, res.srv_bytes, res.ret_bytes)


def goodput_gain_from_telemetry(tel: LinkTelemetry) -> dict[str, Any]:
    """``goodput_gain`` computed straight from a LinkTelemetry — the
    per-scenario (or per-pipe/per-server) form used by the scenario runner,
    which regroups a flat vmapped pipe axis into per-scenario telemetry
    sums before any EngineResult exists (DESIGN.md §8)."""
    return _gain_from_bytes(tel.wire_bytes, tel.srv_bytes, tel.merged_bytes)


def _gain_from_bytes(wire_bytes: int, srv_bytes: int,
                     ret_bytes: int) -> dict[str, Any]:
    naive = 2 * wire_bytes
    baseline = wire_bytes + ret_bytes
    srv = srv_bytes
    return dict(
        baseline_link_bytes=baseline,
        baseline_naive_link_bytes=naive,
        parked_link_bytes=srv,
        link_byte_saving=1.0 - srv / baseline if baseline else 0.0,
        link_byte_saving_naive=1.0 - srv / naive if naive else 0.0,
        goodput_gain=(baseline / srv - 1.0) if srv else 0.0,
        goodput_gain_naive=(naive / srv - 1.0) if srv else 0.0,
    )
