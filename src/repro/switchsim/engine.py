"""Device-resident, multi-pipe PayloadPark simulation engine.

The seed ``simulate()`` drove one ``ParkState`` through a host-side Python
chunk loop with per-chunk ``int(jnp.sum(...))`` syncs — every chunk paid a
dispatch + device->host round trip, and only one pipe existed.  This module
compiles the whole split -> NF-chain -> merge timeline into ONE XLA program:

  * ``lax.scan`` over time steps.  The carry holds ``(ParkState, NF-chain
    states, in-flight ring buffer, step index)``; the per-step ys carry the
    merged chunk plus int32 byte tallies (wire bytes in, server-link bytes),
    so accounting lives on-device and is aggregated once at the end.
  * The in-flight window — the paper's split->merge time delta (~30 us, §4)
    — is a ``window``-deep ring of packet chunks indexed by ``t % window``
    with ``dynamic_index_in_dim`` / ``dynamic_update_index_in_dim``; chunk
    ``t`` is split at step ``t`` and its NF output merges at ``t + window``,
    exactly the seed loop's timeline.
  * ``vmap`` over a leading pipe axis replicates the engine per ingress
    shard — one ``ParkState`` per pipe, mirroring the paper's per-port pipes
    that let one ToR switch service up to 8 NF servers (§6.3.2).  Pipes
    share nothing (the hardware pipes share nothing either); cross-pipe
    goodput is aggregated host-side after the single device program returns.

Semantics are bit-identical to the seed loop (``simulate.simulate_loop``):
padding chunks are all-dead (``alive=False``) and every Split/Merge/NF state
update is predicated on ``alive``, so the padded steps are exact no-ops on
the switch state.  ``tests/test_engine.py`` asserts wire-level equality.

Design notes: DESIGN.md §3.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import counters as C
from repro.core.packet import PacketBatch
from repro.core.park import ParkConfig, ParkState, init_state, merge_fn, split_fn
from repro.nf.chain import Chain, to_explicit_drops


@dataclasses.dataclass
class EngineResult:
    """Result of one engine run (single pipe unless noted).

    ``merged``: (T, chunk, ...) time-major merged output, arrival order.
    ``sent``:   (T, chunk, ...) post-split traffic, or None if not collected.
    ``state``:  final ParkState (leading pipe axis when multi-pipe).
    ``wire_bytes``/``srv_bytes``: exact totals, summed host-side in int64.
    ``srv_bytes`` covers BOTH server-link directions; ``srv_fwd_bytes`` is
    the switch->server direction alone — the bottleneck direction when the
    NF chain drops packets (dropped packets never make the return trip).
    """

    merged: PacketBatch
    sent: PacketBatch | None
    state: ParkState
    counters: dict
    srv_bytes: int
    srv_fwd_bytes: int
    wire_bytes: int


@dataclasses.dataclass
class PipesResult(EngineResult):
    """Aggregated multi-pipe result; per-pipe breakdowns included.

    ``merged``/``sent`` keep the leading pipe axis: (P, T, chunk, ...).
    ``counters`` is the cross-pipe sum; ``per_pipe_counters`` the breakdown.
    """

    per_pipe_counters: list[dict] = dataclasses.field(default_factory=list)
    per_pipe_srv_bytes: list[int] = dataclasses.field(default_factory=list)
    per_pipe_wire_bytes: list[int] = dataclasses.field(default_factory=list)


def _alive_bytes(p: PacketBatch) -> jax.Array:
    return jnp.sum(jnp.where(p.alive, p.pkt_len(), 0))


def _build_scan(cfg: ParkConfig, chain: Chain, window: int,
                explicit_drops: bool, use_kernel: bool, collect_sent: bool):
    """Single-pipe scan body: trace (T+window, chunk, ...) -> ys + final."""

    def run(trace: PacketBatch):
        # All-dead chunks are all-zeros in every field (alive=False == 0),
        # so a zeros ring is a ring of dead chunks.
        ring = jax.tree.map(
            lambda a: jnp.zeros((max(window, 1),) + a.shape[1:], a.dtype),
            trace)
        carry0 = (init_state(cfg), chain.init_state(), ring,
                  jnp.zeros((), jnp.int32))

        def step(carry, cin):
            state, cstates, ring, t = carry
            wire_b = _alive_bytes(cin)
            state, out = split_fn(cfg, state, cin, use_kernel=use_kernel)
            srv_b = _alive_bytes(out)
            cstates, nf_out, dropped, _cycles = chain.run(cstates, out)
            if explicit_drops:
                nf_out = to_explicit_drops(nf_out, dropped)
            if window == 0:
                returning = nf_out
            else:
                slot = jnp.mod(t, window)
                returning = jax.tree.map(
                    lambda r: jax.lax.dynamic_index_in_dim(
                        r, slot, axis=0, keepdims=False), ring)
                ring = jax.tree.map(
                    lambda r, v: jax.lax.dynamic_update_index_in_dim(
                        r, v, slot, axis=0), ring, nf_out)
            srv_fwd_b = srv_b
            srv_b = srv_b + _alive_bytes(returning)
            state, m = merge_fn(cfg, state, returning, use_kernel=use_kernel)
            ys = dict(merged=m, wire_b=wire_b, srv_b=srv_b,
                      srv_fwd_b=srv_fwd_b)
            if collect_sent:
                ys["sent"] = out
            return (state, cstates, ring, t + 1), ys

        (state, _, _, _), ys = jax.lax.scan(step, carry0, trace)
        return state, ys

    return run


@lru_cache(maxsize=None)
def _compiled(cfg: ParkConfig, chain: Chain, window: int,
              explicit_drops: bool, use_kernel: bool, collect_sent: bool,
              pipes: bool):
    run = _build_scan(cfg, chain, window, explicit_drops, use_kernel,
                      collect_sent)
    if pipes:
        run = jax.vmap(run)
    return jax.jit(run)


def _pad_trace(trace: PacketBatch, window: int, axis: int = 0) -> PacketBatch:
    """Append ``window`` all-dead chunks (zeros) along the time axis so the
    last in-flight chunks drain through the scan."""
    if window == 0:
        return trace

    def pad(a):
        shape = list(a.shape)
        shape[axis] = window
        return jnp.concatenate([a, jnp.zeros(shape, a.dtype)], axis=axis)

    return jax.tree.map(pad, trace)


def _finalize(ys: dict, window: int, collect_sent: bool, time_axis: int):
    """Slice the warm-up/drain steps off the ys and sum byte tallies."""
    t_pad = ys["wire_b"].shape[-1]
    t_real = t_pad - window

    def slice_time(a, start, stop):
        idx = [slice(None)] * a.ndim
        idx[time_axis] = slice(start, stop)
        return a[tuple(idx)]

    merged = jax.tree.map(
        lambda a: slice_time(a, window, t_pad), ys["merged"])
    sent = None
    if collect_sent:
        sent = jax.tree.map(lambda a: slice_time(a, 0, t_real), ys["sent"])
    wire = np.asarray(ys["wire_b"], np.int64).sum()
    srv = np.asarray(ys["srv_b"], np.int64).sum()
    srv_fwd = np.asarray(ys["srv_fwd_b"], np.int64).sum()
    return merged, sent, int(wire), int(srv), int(srv_fwd)


def run_engine(
    cfg: ParkConfig,
    chain: Chain,
    trace: PacketBatch,
    window: int = 1,
    explicit_drops: bool = False,
    use_kernel: bool = False,
    collect_sent: bool = False,
) -> EngineResult:
    """Run one pipe over a time-major trace (T, chunk, ...) under one jit.

    Bit-identical to ``simulate.simulate_loop`` on the same trace (the seed
    Python loop), but the whole timeline is a single compiled program.
    """
    trace = _pad_trace(trace, window, axis=0)
    fn = _compiled(cfg, chain, window, explicit_drops, use_kernel,
                   collect_sent, pipes=False)
    state, ys = fn(trace)
    merged, sent, wire, srv, srv_fwd = _finalize(ys, window, collect_sent,
                                                 time_axis=0)
    return EngineResult(
        merged=merged, sent=sent, state=state,
        counters=C.as_dict(state.counters),
        srv_bytes=srv, srv_fwd_bytes=srv_fwd, wire_bytes=wire,
    )


def run_pipes(
    cfg: ParkConfig,
    chain: Chain,
    traces: PacketBatch,
    window: int = 1,
    explicit_drops: bool = False,
    use_kernel: bool = False,
    collect_sent: bool = False,
) -> PipesResult:
    """Run P independent pipes over (P, T, chunk, ...) traces, vmapped.

    Each pipe owns a fresh ``ParkState`` and NF-chain state (the paper's
    per-port pipes share nothing, §6.3.2); one compiled program drives all
    of them.  Byte totals and counters are aggregated across pipes.
    """
    n_pipes = jax.tree.leaves(traces)[0].shape[0]
    traces = _pad_trace(traces, window, axis=1)
    fn = _compiled(cfg, chain, window, explicit_drops, use_kernel,
                   collect_sent, pipes=True)
    state, ys = fn(traces)
    merged, sent, wire, srv, srv_fwd = _finalize(ys, window, collect_sent,
                                                 time_axis=1)
    per_wire = np.asarray(ys["wire_b"], np.int64).sum(axis=-1)
    per_srv = np.asarray(ys["srv_b"], np.int64).sum(axis=-1)
    ctr = np.asarray(state.counters, np.int64)  # (P, C.NUM)
    agg = dict(zip(C.NAMES, (int(v) for v in ctr.sum(axis=0))))
    per_pipe = [dict(zip(C.NAMES, (int(v) for v in ctr[p])))
                for p in range(n_pipes)]
    return PipesResult(
        merged=merged, sent=sent, state=state,
        counters=agg, srv_bytes=srv, srv_fwd_bytes=srv_fwd, wire_bytes=wire,
        per_pipe_counters=per_pipe,
        per_pipe_srv_bytes=[int(v) for v in per_srv],
        per_pipe_wire_bytes=[int(v) for v in per_wire],
    )


def goodput_gain(res: EngineResult) -> dict[str, Any]:
    """Server-link byte saving vs the non-parking baseline.

    Baseline carries every packet whole in BOTH directions (to and from the
    NF server): ``2 * wire_bytes``.  Parking carries headers + un-parked
    tails + the 7-byte PP header.  Positive saving = goodput gain on the
    switch<->server link (the paper's §6.1 metric, byte form).
    """
    baseline = 2 * res.wire_bytes
    saving = 1.0 - res.srv_bytes / baseline if baseline else 0.0
    return dict(
        baseline_link_bytes=baseline,
        parked_link_bytes=res.srv_bytes,
        link_byte_saving=saving,
        goodput_gain=(baseline / res.srv_bytes - 1.0) if res.srv_bytes else 0.0,
    )
