"""Tofino on-chip resource accounting (paper §6.3.2, Table 1).

Models the Match-Action-Unit resources PayloadPark consumes, using public
Tofino-generation constants (the paper omits exact chip details for
confidentiality; §5 footnote):

  * 12 MAU stages per pipe; 80 SRAM blocks of 16 KB per stage (1.28 MB/stage,
    15.36 MB/pipe — consistent with "50-100 MB of stateful SRAM" chip-wide
    for 4 pipes plus packet buffer).
  * register arrays consume whole SRAM blocks; a (M x width) register array
    needs ceil(M * width / 16KB) blocks placed in one stage.
  * PHV capacity 4 kbit; VLIW actions 32 slots/stage.

``utilization`` returns avg/peak per-stage SRAM % plus PHV/VLIW estimates so
the Table 1 benchmark can compare against the paper's reported numbers
(25.94 %/33.75 % for 4 NF servers; 38.23 %/48.75 % for 8).
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.park import BLOCK_BYTES, ParkConfig

STAGES_PER_PIPE = 12
SRAM_BLOCKS_PER_STAGE = 80
SRAM_BLOCK_BYTES = 16 * 1024
STAGE_SRAM_BYTES = SRAM_BLOCKS_PER_STAGE * SRAM_BLOCK_BYTES  # 1.28 MB
PIPE_SRAM_BYTES = STAGES_PER_PIPE * STAGE_SRAM_BYTES          # 15.36 MB
PHV_BITS = 4096
VLIW_SLOTS_PER_STAGE = 32


@dataclasses.dataclass(frozen=True)
class Utilization:
    sram_avg_pct: float
    sram_peak_pct: float
    phv_pct: float
    vliw_pct: float
    sram_bytes: int
    stages_used: int

    def row(self) -> dict:
        return dataclasses.asdict(self)


def _blocks(nbytes: int) -> int:
    return math.ceil(nbytes / SRAM_BLOCK_BYTES)


def _placement(capacity: int, banks: int, nf_servers: int) -> list[int]:
    """Per-stage SRAM blocks for one PayloadPark layout.

    Single source of truth shared by the forward model (``utilization``)
    and the Fig. 14 inversion (``capacity_for_memory_fraction``) so the two
    stay mutually consistent — register arrays consume whole 16 KB blocks,
    replicated per server slice (§6.2.3).
    """
    per_stage_blocks = [0] * STAGES_PER_PIPE
    # Stage 1: tagger registers (TI + CLK, 2 x 2B) — negligible, 1 block.
    per_stage_blocks[0] += 1
    # Stage 2: metadata table: EXP(2B) + CLK(2B) + LEN(2B) per slot.
    per_stage_blocks[1] += _blocks(capacity * 6) * nf_servers
    # Stages 3..N: payload banks, BLOCK_BYTES-wide register arrays striped
    # across the remaining stages (Fig. 4).  Two arrays per stage is typical
    # (two MATs can share a stage when resources allow, §4).
    banks_per_stage = 2
    stage = 2
    placed = 0
    while placed < banks:
        k = min(banks_per_stage, banks - placed)
        per_stage_blocks[stage % STAGES_PER_PIPE] += \
            _blocks(capacity * BLOCK_BYTES) * k * nf_servers
        placed += k
        stage += 1
    return per_stage_blocks


def utilization(cfg: ParkConfig, nf_servers: int = 1) -> Utilization:
    """Resource usage for ``nf_servers`` sharing one pipe's MAU (paper §6.2.3
    statically slices the reserved memory among servers on the same pipe)."""
    per_stage_blocks = _placement(cfg.capacity, cfg.banks, nf_servers)
    banks = cfg.banks

    pcts = [100.0 * b / SRAM_BLOCKS_PER_STAGE for b in per_stage_blocks]
    used = [p for p in pcts if p > 0]
    total_bytes = sum(per_stage_blocks) * SRAM_BLOCK_BYTES

    # PHV: parsed Ethernet+IPv4+UDP (~42B) + PP header (7B) + payload blocks
    # carried through the pipeline (park_bytes) + metadata struct (~8B).
    phv_bits = (42 + 7 + cfg.park_bytes + 8) * 8
    phv_pct = 100.0 * phv_bits / PHV_BITS
    # VLIW: ~2 actions for tagger, 4 for metadata, 1 per bank store/fetch.
    vliw = 2 + 4 + banks
    vliw_pct = 100.0 * vliw / (VLIW_SLOTS_PER_STAGE * STAGES_PER_PIPE)

    return Utilization(
        sram_avg_pct=sum(used) / len(used),
        sram_peak_pct=max(pcts),
        phv_pct=phv_pct,
        vliw_pct=vliw_pct,
        sram_bytes=total_bytes,
        stages_used=sum(1 for b in per_stage_blocks if b),
    )


def capacity_for_memory_fraction(frac: float, cfg: ParkConfig,
                                 nf_servers: int = 1) -> int:
    """Invert the model: the largest table capacity whose *placed* SRAM cost
    fits in ``frac`` of one pipe's SRAM (paper Fig. 14 sweeps 'percentage of
    reserved memory').

    Uses the same ``_placement`` as ``utilization`` — whole 16 KB blocks
    per register array, replicated per server slice — so the inversion
    round-trips against the forward model exactly (the seed divided the
    budget by raw per-slot bytes and ignored both effects, overstating the
    affordable capacity).
    """
    budget = frac * PIPE_SRAM_BYTES

    def cost(m: int) -> int:
        return sum(_placement(m, cfg.banks, nf_servers)) * SRAM_BLOCK_BYTES

    if cost(0) > budget:  # fixed tagger overhead alone does not fit
        return 0
    hi = 1
    while cost(hi) <= budget and hi < PIPE_SRAM_BYTES:
        hi *= 2
    lo = hi // 2 if hi > 1 else 0
    # invariant: cost(lo) <= budget < cost(hi)
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if cost(mid) <= budget:
            lo = mid
        else:
            hi = mid
    return lo
