"""NF chain composition (paper §1: "NFs are often connected together in an NF
chain, such as FW-NAT") and the Explicit-Drop integration point (§6.2.4).

A chain is an ordered list of NFs; each NF is a pure function
``(state, pkts) -> (state, pkts, drop_mask, cycles)`` touching only headers.
``run`` threads the states, ORs the drop masks and sums the per-packet cycle
costs (used by the analytic performance model, switchsim.perfmodel).

``to_explicit_drops`` models the paper's 50-line OpenNetVM change: packets the
chain dropped, whose payload is parked (ENB=1), are turned into truncated
OP=drop notifications sent back to the switch so Merge can free the slot
immediately instead of waiting for expiry-based eviction.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.packet import OP_DROP, PacketBatch


@dataclasses.dataclass(frozen=True)
class Chain:
    nfs: tuple  # sequence of NF dataclasses (Firewall, Nat, MaglevLB, MacSwap)

    def init_state(self) -> tuple:
        return tuple(nf.init_state() for nf in self.nfs)

    def run(self, states: tuple, pkts: PacketBatch, backend=None, ctx=None):
        """Returns (new_states, pkts_out, dropped_by_chain, total_cycles).

        ``backend`` (``repro.backend.BackendConfig`` / name / None) selects
        each NF's hot-path primitive implementation and is threaded to every
        NF uniformly.  ``ctx`` is the per-step environment dict from the
        fault-injection layer (DESIGN.md §10) — currently ``{"lb_up": bool
        scalar}`` — threaded to every NF the same way; None means healthy."""
        dropped = jnp.zeros_like(pkts.alive)
        total_cycles = 0.0
        new_states = []
        for nf, st in zip(self.nfs, states):
            st, pkts, drop, cycles = nf(st, pkts, backend=backend, ctx=ctx)
            dropped = dropped | drop
            total_cycles += cycles
            new_states.append(st)
        return tuple(new_states), pkts, dropped, total_cycles

    def state_counters(self, states: tuple) -> dict:
        """Aggregate the NF-private counters carried in chain state (e.g.
        NAT's ``nat_stale_hits``), as a flat name->scalar dict.  NFs opt in
        by defining ``state_counters(state)``; names must be unique across
        the chain (each NF prefixes its own)."""
        out: dict = {}
        for nf, st in zip(self.nfs, states):
            fn = getattr(nf, "state_counters", None)
            if fn is None:
                continue
            for name, val in fn(st).items():
                if name in out:
                    raise ValueError(f"duplicate NF counter {name!r}")
                out[name] = val
        return out

    def cycle_costs(self, backend=None) -> tuple[float, ...]:
        """Per-NF CPU cycle costs, in chain order, for the analytic model
        (perfmodel wants the slowest single NF — OpenNetVM pins each NF to
        its own core, §6.1).  Probed by running each NF on one dead packet
        through the SAME backend dispatch the simulation uses — a
        Pallas-backed NF is probed on the Pallas path, so the analytic
        model can never silently mix backends; every NF reports its cycle
        cost as a per-call Python float."""
        from repro.core.packet import dead_batch
        probe = dead_batch(1, 16)
        costs = []
        for nf in self.nfs:
            _, _, _, cycles = nf(nf.init_state(), probe, backend=backend)
            costs.append(float(cycles))
        return tuple(costs)


def to_explicit_drops(pkts: PacketBatch, dropped) -> PacketBatch:
    """Convert chain-dropped, parked packets into OP=drop notifications.

    Mirrors the paper §6.2.4: "The NF framework marks an incoming packet as
    dropped by changing the opcode, truncating the packet payload, and sending
    the resulting packet back to the switch."
    """
    notify = dropped & pkts.pp_valid & (pkts.pp_enb == 1)
    return pkts.replace(
        alive=pkts.alive | notify,           # resurrect as a notification
        payload_len=jnp.where(notify, 0, pkts.payload_len),
        payload=jnp.where(notify[:, None], 0, pkts.payload),
        pp_op=jnp.where(notify, OP_DROP, pkts.pp_op),
    )
