"""Shallow network functions (paper §6.1): header-only packet processing."""
