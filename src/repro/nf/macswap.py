"""MAC address swapper NF with a tunable busy-loop cost knob.

Paper §6.1/§6.3.3: "To create NFs of varying computational cost, we take a MAC
address swapper and add a busy loop" — NF-Light/Medium/Heavy are ~50/300/570
average CPU cycles per packet.  The busy loop affects only the analytic
performance model (cycles), not the functional transform.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.packet import PacketBatch

NF_LIGHT = 50.0
NF_MEDIUM = 300.0
NF_HEAVY = 570.0


@dataclasses.dataclass(frozen=True)
class MacSwap:
    cycles: float = NF_LIGHT

    def init_state(self):
        return ()

    def __call__(self, state, pkts: PacketBatch, backend=None, ctx=None):
        out = pkts.replace(
            dst_mac=jnp.where(pkts.alive, pkts.src_mac, pkts.dst_mac),
            src_mac=jnp.where(pkts.alive, pkts.dst_mac, pkts.src_mac),
        )
        drop = jnp.zeros_like(pkts.alive)
        return state, out, drop, self.cycles
