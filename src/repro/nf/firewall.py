"""Firewall NF: linear probe through a blocked-IP Access Control List.

Paper §6.1: "The firewall linearly probes through a list of blocked IP
addresses. The firewall in the three-NF chain has 20 rules, and the two-NF
chain has a single rule in its firewall."  §6.2.4 varies the proportion of
blocked addresses to control the drop rate.

Header-only by construction: reads ``src_ip`` exclusively.  The rule match
is the ``acl_match`` primitive of the dataplane-backend registry
(``repro.backend``, DESIGN.md §9): the jnp reference and the Pallas kernel
(repro.kernels.acl_match) are selected by the ``backend`` argument threaded
down from the chain.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.backend import dispatch
from repro.core.packet import PacketBatch

# Rough per-rule linear-probe cost in CPU cycles, calibrated so a 20-rule
# firewall lands near the paper's NF-Light..Medium band (§6.3.3).
CYCLES_PER_RULE = 6.0
CYCLES_BASE = 40.0


@dataclasses.dataclass(frozen=True)
class Firewall:
    """Stateless ACL firewall; ``rules`` is a tuple of blocked src IPs."""

    rules: tuple[int, ...]

    def init_state(self):
        return jnp.asarray(list(self.rules), jnp.int32).reshape(-1)

    def __call__(self, state, pkts: PacketBatch, backend=None, ctx=None):
        rules = state  # (R,) int32
        # Linear probe: compare every packet against every rule.
        blocked = dispatch("acl_match", backend)(pkts.src_ip, rules)
        drop = pkts.alive & blocked
        out = pkts.replace(alive=pkts.alive & ~blocked)
        cycles = CYCLES_BASE + CYCLES_PER_RULE * rules.shape[0]
        return state, out, drop, cycles
