"""NAT NF, modelled on MazuNAT (paper §6.1, from NetBricks/Click).

Stateful source-NAT: the first packet of a flow (src_ip, src_port) allocates
an external port from a monotonically increasing counter and installs a
mapping in a linear-probed hash table; subsequent packets of the flow are
rewritten identically.  Rewrites ``src_ip -> nat_ip`` and ``src_port`` to the
mapped external port.  Header-only: payload is never touched.

Lookups probe a fixed depth (P4-style bounded work); inserts are sequential
via ``lax.scan`` because two same-flow packets inside one batch must receive
the same mapping — the same atomic register discipline PayloadPark's tagger
needs (P4 guarantees it in hardware; scan reproduces it).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.packet import PacketBatch

PROBE_DEPTH = 8
CYCLES = 80.0  # amortized hash+rewrite (calibrated to Fig. 8, see perfmodel)


def _hash(ip, port, capacity):
    """int32 avalanche mix of the flow key (wraps like uint32).

    Constants are the murmur3 finalizer multipliers written as signed int32
    two's-complement (0x9E3779B9, 0x85EBCA6B, 0xC2B2AE35)."""
    h = ip ^ jnp.int32(-1640531527)
    h = (h * jnp.int32(-2048144789)) ^ port
    h = h ^ (h >> 13)
    h = h * jnp.int32(-1028477379)
    return (h & jnp.int32(0x7FFFFFFF)) % capacity


@dataclasses.dataclass(frozen=True)
class Nat:
    nat_ip: int = 0x0A000001  # 10.0.0.1
    capacity: int = 1 << 14   # flow-table slots (power of two)
    base_port: int = 10000

    def init_state(self):
        return dict(
            key_ip=jnp.full((self.capacity,), -1, jnp.int32),
            key_port=jnp.full((self.capacity,), -1, jnp.int32),
            ports=jnp.zeros((self.capacity,), jnp.int32),
            next_port=jnp.asarray(self.base_port, jnp.int32),
        )

    def __call__(self, state, pkts: PacketBatch):
        cap = self.capacity

        def step(carry, x):
            key_ip, key_port, ports, next_port = carry
            ip, port, alive = x
            h = _hash(ip, port, cap)
            slot = jnp.int32(-1)
            free = jnp.int32(-1)
            for i in range(PROBE_DEPTH):
                idx = (h + i) % cap
                hit_i = (key_ip[idx] == ip) & (key_port[idx] == port)
                free_i = key_ip[idx] == -1
                slot = jnp.where((slot < 0) & hit_i, idx, slot)
                free = jnp.where((free < 0) & free_i, idx, free)
            hit = slot >= 0
            can_insert = (~hit) & (free >= 0) & alive
            idx = jnp.where(hit, slot, jnp.where(free >= 0, free, 0))
            key_ip = jnp.where(can_insert, key_ip.at[idx].set(ip), key_ip)
            key_port = jnp.where(can_insert, key_port.at[idx].set(port), key_port)
            ports = jnp.where(can_insert, ports.at[idx].set(next_port), ports)
            mapped = jnp.where(hit | can_insert, ports[idx], -1)
            next_port = jnp.where(can_insert, next_port + 1, next_port)
            return (key_ip, key_port, ports, next_port), mapped

        carry0 = (state["key_ip"], state["key_port"], state["ports"],
                  state["next_port"])
        (key_ip, key_port, ports, next_port), mapped = jax.lax.scan(
            step, carry0, (pkts.src_ip, pkts.src_port, pkts.alive)
        )
        ok = pkts.alive & (mapped >= 0)
        # Table overflow: drop the packet (a real NAT would too).
        drop = pkts.alive & (mapped < 0)
        out = pkts.replace(
            src_ip=jnp.where(ok, self.nat_ip, pkts.src_ip),
            src_port=jnp.where(ok, mapped, pkts.src_port),
            alive=pkts.alive & ~drop,
        )
        new_state = dict(key_ip=key_ip, key_port=key_port, ports=ports,
                         next_port=next_port)
        return new_state, out, drop, CYCLES
