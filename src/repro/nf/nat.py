"""NAT NF, modelled on MazuNAT (paper §6.1, from NetBricks/Click).

Stateful source-NAT with *bounded* resources.  The first packet of a flow
(src_ip, src_port) claims a slot in a linear-probed hash table and is mapped
to the external port **owned by that slot** (``base_port + slot``), so a
mapping can never leave the valid uint16 range; the configuration is
validated up front (``base_port + capacity - 1 <= 65535``).  The seed
implementation allocated ports from a monotonically increasing counter that
overflowed 65535 after ~55k flows and emitted invalid ``src_port`` values —
the per-slot port is the JAX-friendly equivalent of the free-list a real NAT
keeps: a port returns to service exactly when its slot expires.

Idle flows expire EXP-style, mirroring ``core.park``'s expiry discipline:
every mapping carries an expiry counter refreshed to ``max_exp`` on use, and
a new flow that finds neither its mapping nor a free slot ages every slot in
its probe window (CLOCK-style).  Slots that reach zero are reclaimed — with
their ports — by later arrivals.  Under flow churn beyond ``capacity`` this
turns the seed's *permanent* drops (which skewed ≥16k-flow single-pipe
goodput traces; see ``benchmarks/bench_pipeline``) into transient drops
while a neighbourhood ages out.

CLOCK-aging stale-mapping rule: when a flow returns *after* its slot aged
out (exp==0, keys still in place), the slot's port is no longer owned by
the flow — CLOCK may already have re-issued it to a newcomer, so silently
refreshing the old binding would translate two flows onto one external
port.  Such packets are counted ``nat_stale_hits`` and dropped, and the
dead binding is torn down so the flow's next packet re-binds cleanly
(possibly to a different port — exactly what a real NAT's expired-mapping
path does).  The counter rides the chain's ``state_counters`` channel into
engine results and the engine≡loop oracle.

Rewrites ``src_ip -> nat_ip`` and ``src_port`` to the mapped external port.
Header-only: payload is never touched.

Lookups probe a fixed depth (P4-style bounded work); inserts are sequential
via ``lax.scan`` because two same-flow packets inside one batch must receive
the same mapping — the same atomic register discipline PayloadPark's tagger
needs (P4 guarantees it in hardware; scan reproduces it).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.packet import PacketBatch

PROBE_DEPTH = 8
CYCLES = 80.0  # amortized hash+rewrite (calibrated to Fig. 8, see perfmodel)


def _hash(ip, port, capacity):
    """int32 avalanche mix of the flow key (wraps like uint32).

    Constants are the murmur3 finalizer multipliers written as signed int32
    two's-complement (0x9E3779B9, 0x85EBCA6B, 0xC2B2AE35)."""
    h = ip ^ jnp.int32(-1640531527)
    h = (h * jnp.int32(-2048144789)) ^ port
    h = h ^ (h >> 13)
    h = h * jnp.int32(-1028477379)
    return (h & jnp.int32(0x7FFFFFFF)) % capacity


@dataclasses.dataclass(frozen=True)
class Nat:
    nat_ip: int = 0x0A000001  # 10.0.0.1
    capacity: int = 1 << 14   # flow-table slots (power of two)
    base_port: int = 10000
    max_exp: int = 2          # EXP-style flow expiry (cf. core.park max_exp)

    def __post_init__(self):
        if self.capacity < PROBE_DEPTH:
            raise ValueError(
                f"capacity ({self.capacity}) must be >= PROBE_DEPTH "
                f"({PROBE_DEPTH})")
        if self.max_exp < 1:
            raise ValueError(f"max_exp must be >= 1, got {self.max_exp}")
        top = self.base_port + self.capacity - 1
        if not (0 < self.base_port and top <= 65535):
            raise ValueError(
                f"port space [{self.base_port}, {top}] exceeds the valid "
                f"uint16 range; shrink capacity or lower base_port")

    def init_state(self):
        return dict(
            key_ip=jnp.full((self.capacity,), -1, jnp.int32),
            key_port=jnp.full((self.capacity,), -1, jnp.int32),
            exp=jnp.zeros((self.capacity,), jnp.int32),  # 0 = free slot
            stale_hits=jnp.zeros((), jnp.int32),
        )

    def state_counters(self, state) -> dict:
        """NF-private counters surfaced through Chain.state_counters."""
        return {"nat_stale_hits": state["stale_hits"]}

    def __call__(self, state, pkts: PacketBatch, backend=None, ctx=None):
        # header-only table logic; no registry primitive applies, but the
        # chain threads ``backend``/``ctx`` uniformly through every NF
        cap = self.capacity

        def step(carry, x):
            key_ip, key_port, exp = carry
            ip, port, alive = x
            h = _hash(ip, port, cap)
            slot = jnp.int32(-1)
            free = jnp.int32(-1)
            stale = jnp.int32(-1)
            for i in range(PROBE_DEPTH):
                idx = (h + i) % cap
                live_i = exp[idx] > 0
                match_i = (key_ip[idx] == ip) & (key_port[idx] == port)
                slot = jnp.where((slot < 0) & live_i & match_i, idx, slot)
                stale = jnp.where((stale < 0) & ~live_i & match_i, idx, stale)
                free = jnp.where((free < 0) & ~live_i, idx, free)
            hit = alive & (slot >= 0)
            # The flow's mapping aged out (CLOCK) while packets were still
            # in flight: the slot's port may already be re-issued, so the
            # old binding must NOT silently translate.  Count, drop, and
            # tear the dead binding down so the next packet re-binds.
            stale_hit = alive & (slot < 0) & (stale >= 0)
            can_insert = alive & (slot < 0) & ~stale_hit & (free >= 0)
            idx = jnp.where(hit, slot, jnp.where(free >= 0, free, 0))
            key_ip = jnp.where(can_insert, key_ip.at[idx].set(ip), key_ip)
            key_port = jnp.where(can_insert, key_port.at[idx].set(port),
                                 key_port)
            sidx = jnp.clip(stale, 0, cap - 1)
            key_ip = jnp.where(stale_hit, key_ip.at[sidx].set(-1), key_ip)
            key_port = jnp.where(stale_hit, key_port.at[sidx].set(-1),
                                 key_port)
            # use refreshes the expiry (core.park's EXP discipline)
            exp = jnp.where(hit | can_insert,
                            exp.at[idx].set(self.max_exp), exp)
            mapped = jnp.where(hit | can_insert,
                               jnp.int32(self.base_port) + idx, -1)
            # CLOCK-style aging under pressure: a flow that found neither
            # its mapping nor a free slot ages every slot it probed, so a
            # full neighbourhood frees after max_exp failed arrivals.
            exhausted = alive & (slot < 0) & (free < 0)
            probed = (h + jnp.arange(PROBE_DEPTH)) % cap
            aged = jnp.maximum(exp.at[probed].add(-1), 0)
            exp = jnp.where(exhausted, aged, exp)
            return (key_ip, key_port, exp), (mapped, stale_hit)

        carry0 = (state["key_ip"], state["key_port"], state["exp"])
        (key_ip, key_port, exp), (mapped, stale_hit) = jax.lax.scan(
            step, carry0, (pkts.src_ip, pkts.src_port, pkts.alive)
        )
        ok = pkts.alive & (mapped >= 0)
        # Table exhausted in this probe window, or a stale binding: drop (a
        # real NAT would too, until expiry/re-binding restores a port).
        drop = pkts.alive & (mapped < 0)
        out = pkts.replace(
            src_ip=jnp.where(ok, self.nat_ip, pkts.src_ip),
            src_port=jnp.where(ok, mapped, pkts.src_port),
            alive=pkts.alive & ~drop,
        )
        new_state = dict(
            key_ip=key_ip, key_port=key_port, exp=exp,
            stale_hits=state["stale_hits"] + jnp.sum(
                stale_hit.astype(jnp.int32)))
        return new_state, out, drop, CYCLES
