"""Maglev L4 load balancer NF (paper §6.1, based on Eisenbud et al. NSDI'16).

Builds the Maglev consistent-hashing lookup table at configuration time (the
permutation fill is inherently sequential and runs once, in numpy), then
performs vectorized per-packet backend selection: hash the 5-tuple, index the
lookup table, rewrite ``dst_ip`` to the chosen backend VIP target.  The
per-packet selection — the LB's only per-packet hot spot — is the
``maglev_select`` primitive of the dataplane-backend registry
(``repro.backend``, DESIGN.md §9): jnp reference in ``repro.backend.ref``,
Pallas kernel in ``repro.kernels.maglev``, chosen by the ``backend``
argument threaded down from the chain.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.backend import dispatch
from repro.core.packet import PacketBatch

CYCLES = 120.0  # hash + table lookup + rewrite


def _mix64(salt: int, b: int) -> int:
    """Deterministic splitmix64 finalizer over (salt, backend).

    Python's ``hash(str)`` is salted per process (PYTHONHASHSEED), which
    would rebuild a *different* lookup table in every worker — breaking
    cross-process backend stability and committed benchmark baselines.
    """
    x = (b * 0x9E3779B97F4A7C15 + salt * 0xBF58476D1CE4E5B9) & (1 << 64) - 1
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & (1 << 64) - 1
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & (1 << 64) - 1
    return x ^ (x >> 31)


def build_table(backends: tuple[int, ...], table_size: int) -> np.ndarray:
    """Maglev population: each backend fills preferred slots by (offset, skip)."""
    n = len(backends)
    offset = np.array([_mix64(1, b) % table_size for b in backends])
    skip = np.array([_mix64(2, b) % (table_size - 1) + 1 for b in backends])
    entry = np.full(table_size, -1, np.int32)
    nxt = np.zeros(n, np.int64)
    filled = 0
    while filled < table_size:
        for i in range(n):
            c = (offset[i] + nxt[i] * skip[i]) % table_size
            while entry[c] >= 0:
                nxt[i] += 1
                c = (offset[i] + nxt[i] * skip[i]) % table_size
            entry[c] = i
            nxt[i] += 1
            filled += 1
            if filled == table_size:
                break
    return entry


def degraded_table(backends: tuple[int, ...], table_size: int,
                   dead: int) -> np.ndarray:
    """Lookup table with backend index ``dead`` removed, entries remapped
    to the *original* backend indexing.

    This is what a Maglev control plane pushes when a health check fails:
    the surviving backends re-run the population over the same table size,
    so the dead backend's slots are redistributed while the vast majority
    of surviving slots keep their assignment (the consistent-hashing
    minimal-disruption property ``tests/test_chain_lb.py`` asserts).
    """
    surviving = tuple(b for i, b in enumerate(backends) if i != dead)
    orig_idx = np.array([i for i in range(len(backends)) if i != dead],
                        np.int32)
    return orig_idx[build_table(surviving, table_size)]


@dataclasses.dataclass(frozen=True)
class MaglevLB:
    backends: tuple[int, ...] = tuple(0x0A000100 + i for i in range(8))
    table_size: int = 251  # small prime; Maglev paper uses 65537 in prod
    # Fault-injection hook (DESIGN.md §10): when >= 0, state additionally
    # carries the degraded table with this backend removed, and the per-step
    # ``ctx["lb_up"]`` mask selects live vs degraded — the kill->recover
    # round trip is pure data flow, no recompile at the fault boundary.
    fault_target: int = -1

    def __post_init__(self):
        if self.fault_target >= len(self.backends):
            raise ValueError(
                f"fault_target {self.fault_target} out of range for "
                f"{len(self.backends)} backends")

    def init_state(self):
        state = dict(
            table=jnp.asarray(build_table(self.backends, self.table_size)),
            backend_ips=jnp.asarray(list(self.backends), jnp.int32),
        )
        if self.fault_target >= 0:
            state["table_down"] = jnp.asarray(degraded_table(
                self.backends, self.table_size, self.fault_target))
        return state

    def __call__(self, state, pkts: PacketBatch, backend=None, ctx=None):
        table = state["table"]
        if self.fault_target >= 0 and ctx is not None and "lb_up" in ctx:
            table = jnp.where(ctx["lb_up"], table, state["table_down"])
        new_dst = dispatch("maglev_select", backend)(
            pkts.src_ip, pkts.dst_ip, pkts.src_port, pkts.dst_port,
            pkts.proto, table, state["backend_ips"])
        out = pkts.replace(
            dst_ip=jnp.where(pkts.alive, new_dst, pkts.dst_ip))
        drop = jnp.zeros_like(pkts.alive)
        return state, out, drop, CYCLES
