"""Training substrate: optimizer, train step, data, checkpointing."""
