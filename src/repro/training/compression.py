"""int8 error-feedback gradient compression for the DP all-reduce.

Distributed-optimization trick (system brief): on bandwidth-bound meshes the
data-parallel gradient all-reduce can dominate; quantizing gradients to int8
with per-tensor scale cuts DP collective bytes 4x (f32) / 2x (bf16).  The
local quantization residual is carried in an error-feedback buffer and added
back before the next step's quantization — which preserves convergence
(Karimireddy et al., 2019).

``compress_decompress`` is the *simulation-friendly* form: it applies the
quantize -> (all-reduce happens outside, on int8 values) -> dequantize
round-trip so tests can verify convergence behaviour on one host.  The
shard_map collective form for a real mesh is ``quantized_psum``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, err_state):
    """Error-feedback int8 round-trip.  Returns (grads', new_err_state)."""

    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = _quant(x)
        deq = _dequant(q, scale)
        return deq.astype(g.dtype), x - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def quantized_psum(x, axis_name: str):
    """int8-quantized psum for use inside shard_map: quantize locally,
    all-reduce the int32-upcast payload (wire bytes ~= 1/4 of f32), rescale by
    the max scale.  Approximate (scale unification) — the error-feedback
    buffer absorbs the difference."""
    q, scale = _quant(x.astype(jnp.float32))
    scale_max = jax.lax.pmax(scale, axis_name)
    # requantize against the common scale so the integer sum is coherent
    q2 = jnp.clip(jnp.round(x.astype(jnp.float32) / scale_max),
                  -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q2, axis_name)
    return total.astype(jnp.float32) * scale_max
