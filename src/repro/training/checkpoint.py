"""Sharded checkpoint save/restore with resharding on load.

Fault-tolerance contract (DESIGN.md §5):
  * ``save`` writes one .npz per host (its addressable shards only) plus a
    JSON manifest; writes go to a temp dir renamed atomically, so a crash
    mid-save never corrupts the latest checkpoint.
  * ``restore`` reassembles the global arrays and re-places them under the
    *current* mesh/shardings — which may differ from the saving run's
    (elastic rescale: train on 512 chips, restart on 256).
  * ``latest_step`` + launch/train.py give automatic resume-after-failure.
  * saves can run asynchronously (background thread) so the train loop only
    blocks on the previous save's completion — checkpoint bandwidth overlaps
    compute.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flat(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def _unflatten_like(template, flat: dict[str, Any]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    tdef = jax.tree.structure(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        leaves.append(flat[key])
    return jax.tree.unflatten(tdef, leaves)


def save(ckpt_dir: str, step: int, tree, process_index: int = 0,
         blocking: bool = True) -> Optional[threading.Thread]:
    """Write ``tree`` under ckpt_dir/step_<N>/ atomically."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp{process_index}"

    host_data = {}
    for key, leaf in _flat(tree).items():
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)  # npz has no bf16; restore re-views
        host_data[key.replace("/", "~")] = arr

    def _write():
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"host{process_index}.npz"), **host_data)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "keys": sorted(host_data)}, f)
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.replace(tmp, final)

    if blocking:
        _write()
        return None
    th = threading.Thread(target=_write, daemon=True)
    th.start()
    return th


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp0")
             and os.path.isfile(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, template, shardings=None):
    """Load and re-place under ``shardings`` (a pytree of NamedSharding or
    None).  The template supplies structure and dtypes; shapes are validated.
    Resharding happens in jax.device_put — loading onto a different mesh than
    the one that saved is the elastic-rescale path."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    files = [f for f in os.listdir(d) if f.endswith(".npz")]
    flat: dict[str, Any] = {}
    for f in files:
        with np.load(os.path.join(d, f)) as z:
            for k in z.files:
                flat[k.replace("~", "/")] = z[k]
    tree = _unflatten_like(template, flat)

    def place(leaf, tmpl, sh):
        if tmpl.dtype == jnp.bfloat16 and leaf.dtype == np.uint16:
            leaf = leaf.view(jnp.bfloat16)
        arr = jnp.asarray(leaf, dtype=tmpl.dtype)
        assert arr.shape == tmpl.shape, (arr.shape, tmpl.shape)
        return jax.device_put(arr, sh) if sh is not None else arr

    if shardings is None:
        return jax.tree.map(lambda v, t: place(v, t, None), tree, template)
    return jax.tree.map(place, tree, template, shardings)
