"""AdamW with decoupled weight decay and global-norm clipping.

Mixed precision: params may be bf16; first/second moments are f32 (standard
large-model practice).  Implemented directly (no optax dependency) so the
optimizer state pytree mirrors the param tree exactly — which keeps sharding
rules (distributed/sharding.py) and checkpointing trivial: moments inherit
the param's PartitionSpec.
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def init_opt_state(params) -> dict:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.minimum(warm, 1.0) * jnp.where(step < cfg.warmup_steps,
                                                       1.0, cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, opt_state, grads):
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v, g):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    flat_g = tdef.flatten_up_to(grads)
    out = [upd(p, m, v, g) for p, m, v, g in
           zip(flat_p, flat_m, flat_v, flat_g)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
