"""The jit-compiled training step: loss -> grad -> (optional compression)
-> AdamW.  This is what launch/dryrun.py lowers for ``train_4k`` cells and
what launch/train.py executes."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.lm import LM, Shard, _identity
from repro.training import optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: opt.AdamWConfig = opt.AdamWConfig()
    microbatch: int = 0           # 0 = no gradient accumulation
    compress_grads: bool = False  # int8 error-feedback DP all-reduce


def init_train_state(lm: LM, key) -> dict:
    params = lm.init_params(key)
    return {"params": params, "opt": opt.init_opt_state(params)}


def train_step(lm: LM, tcfg: TrainConfig, state: dict, batch: dict,
               shard: Shard = _identity,
               grad_transform: Optional[Callable] = None):
    """One optimizer step.  ``grad_transform`` hooks gradient compression
    (training/compression.py) between backprop and AdamW."""

    b = batch["tokens"].shape[0]

    def loss_fn(params, bslice):
        loss, metrics = lm.loss(params, bslice, shard)
        return loss, metrics

    def slice_batch(i, mb):
        def sl(a):
            axis = 1 if (a.ndim >= 2 and a.shape[0] == 3
                         and a.shape[1] == b) else 0
            return jax.lax.dynamic_slice_in_dim(a, i * mb, mb, axis=axis)
        return jax.tree.map(sl, batch)

    if tcfg.microbatch and tcfg.microbatch < b:
        # gradient accumulation over microbatches (sequential, memory-lean)
        mb = tcfg.microbatch
        assert b % mb == 0, (b, mb)
        n = b // mb

        def one(i, acc):
            grads_acc, loss_acc = acc
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"], slice_batch(i, mb))
            grads_acc = jax.tree.map(
                lambda ga, gi: ga + gi.astype(jnp.float32), grads_acc, g)
            return grads_acc, loss_acc + loss

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             state["params"])
        grads, loss = jax.lax.fori_loop(0, n, one, (zeros, jnp.zeros(())))
        grads = jax.tree.map(lambda g: g / n, grads)
        loss = loss / n
        metrics = {"ce": loss, "aux": jnp.zeros(())}
    else:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"], batch)

    if grad_transform is not None:
        grads = grad_transform(grads)

    params, opt_state, opt_metrics = opt.apply_updates(
        tcfg.adamw, state["params"], state["opt"], grads)
    metrics = dict(metrics, **opt_metrics, loss=loss)
    return {"params": params, "opt": opt_state}, metrics
