"""Deterministic synthetic token pipeline with per-host sharding.

Production-shaped data layer: a seeded, stateless ``batch_at(step)`` API so
that (1) restarts resume mid-epoch with no duplicated/skipped batches (the
checkpoint stores only the step), (2) each host materializes exactly its own
shard of the global batch (``host_slice``), and (3) elastic rescaling changes
the per-host slice without changing the global stream.

Synthetic text: a mixture of Zipf-distributed unigrams and deterministic
n-gram structure so losses actually decrease during the example runs
(pure-uniform tokens would pin CE at log V).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


def _zipf_probs(cfg: DataConfig) -> np.ndarray:
    ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
    p = ranks ** -cfg.zipf_a
    return (p / p.sum()).astype(np.float32)


@dataclasses.dataclass
class SyntheticStream:
    cfg: DataConfig

    def __post_init__(self):
        self._probs = jnp.asarray(_zipf_probs(self.cfg))

    def batch_at(self, step: int, host_index: int = 0, host_count: int = 1):
        """Global batch for ``step``, sliced for this host.  Pure function of
        (seed, step) — the restart/elasticity contract."""
        cfg = self.cfg
        assert cfg.global_batch % host_count == 0
        per_host = cfg.global_batch // host_count
        key = jax.random.fold_in(jax.random.key(cfg.seed), step)
        key = jax.random.fold_in(key, host_index)
        k1, k2 = jax.random.split(key)
        shape = (per_host, cfg.seq_len + 1)
        base = jax.random.categorical(
            k1, jnp.log(self._probs)[None, :], shape=shape)
        # inject learnable bigram structure: every odd position repeats a
        # deterministic function of its predecessor with prob ~1/2
        follow = (base * 31 + 7) % cfg.vocab_size
        gate = jax.random.bernoulli(k2, 0.5, shape)
        seq = jnp.where(gate & (jnp.arange(cfg.seq_len + 1) % 2 == 1),
                        jnp.roll(follow, 1, axis=1), base)
        seq = seq.astype(jnp.int32)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}
