"""Scenario matrix subsystem (DESIGN.md §8).

Declarative scenario specs (``spec``), a registry of named families
(``registry``, populated by ``matrix`` with the paper's evaluation grid),
and the vmapped sweep runner (``runner``) that executes trace-compatible
points as one compiled XLA program.
"""
from repro.scenarios.adversarial import (adversarial_family, bounds_for,
                                         degradation_block,
                                         degradation_metrics)
from repro.scenarios.matrix import pipeline_grid, recirc_grid
from repro.scenarios.registry import family, names, register
from repro.scenarios.runner import (OracleMismatch, ScenarioResult,
                                    default_rows, run_matrix, verify_oracle)
from repro.scenarios.spec import (ScenarioSpec, build_chain, compile_key,
                                  grid, make_packets, resolve_workload,
                                  steer)

__all__ = [
    "family", "names", "register", "pipeline_grid", "recirc_grid",
    "OracleMismatch", "ScenarioResult", "default_rows", "run_matrix",
    "verify_oracle",
    "ScenarioSpec", "build_chain", "compile_key", "grid", "make_packets",
    "resolve_workload", "steer",
    "adversarial_family", "bounds_for", "degradation_block",
    "degradation_metrics",
]
