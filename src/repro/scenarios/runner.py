"""Sweep runner: one vmapped XLA program per trace-compatible group.

The seed-era benches each hand-rolled a Python loop around the scanned
engine — one ``run_pipes`` dispatch per sweep point, one compile per
distinct (cfg, chain, shape) even when points only differed in traffic.
This runner is the single sweep path (DESIGN.md §8):

  1. every scenario point is expanded to its (P_i, T, chunk, ...) traces;
  2. points whose ``compile_key`` matches are **batched**: their pipe axes
     are concatenated into one (sum P_i, T, chunk, ...) stack and executed
     by ONE ``engine.run_pipes`` call — pipes share nothing, so a flat
     vmapped pipe axis is indifferent to which scenario each pipe belongs
     to, and one compile covers the whole group (workload / seed / flow
     axes share a compile this way);
  3. per-scenario results are regrouped from the engine's per-pipe
     counters/telemetry/occupancy slices;
  4. shape-changing axes (capacity, recirc_frac, chunk, window, chain)
     land in different groups and rely on the engine's ``lru_cache`` keyed
     compile cache — a re-run with the same key never re-traces.

``verify_oracle`` re-runs any point through the host-loop reference
(``simulate_loop``) pipe by pipe and asserts counters + telemetry equality
— the engine≡loop invariant the repo's tests enforce, exposed here so
every benchmark asserts it the same way.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.scenarios.spec import (ScenarioSpec, build_chain, compile_key,
                                  make_packets, steer)
from repro.switchsim import engine as E
from repro.switchsim import faults as F
from repro.switchsim.results import flat_summary
from repro.switchsim.simulate import simulate_loop
from repro.switchsim.telemetry import LinkTelemetry, sum_telemetry
from repro.core import counters as C


@dataclasses.dataclass
class ScenarioResult:
    """One executed scenario point (cross-pipe aggregates + per-pipe
    breakdowns), plus the derived goodput-gain dict and enough context
    (chain cycle costs, steering stats) for the benches' model glue."""

    spec: ScenarioSpec
    counters: dict
    telemetry: LinkTelemetry
    per_pipe_counters: list[dict]
    per_pipe_telemetry: list[LinkTelemetry]
    per_pipe_peak_occupancy: list[int]
    nf_counters: dict
    per_pipe_nf_counters: list[dict]
    per_pipe_occ_series: object   # (P, steps) parked-slot occupancy
    gain: dict
    steer_stats: dict
    nf_cycles: tuple[float, ...]
    wall_s: float       # this point's share of its group's wall time
    group_size: int     # points that shared the compiled program
    group_wall_s: float
    # the prepared traffic/chain/traces this result was computed from;
    # verify_oracle reuses it instead of regenerating (repr-noise excluded)
    prepared: "_Prepared" = dataclasses.field(default=None, repr=False)

    @property
    def peak_occupancy(self) -> int:
        return max(self.per_pipe_peak_occupancy)

    @property
    def alive_offered(self) -> int:
        """Offered packets that reached a pipe (steering overflow excluded)."""
        return (sum(self.steer_stats["per_pipe_arrivals"])
                - self.steer_stats["overflow"])

    def summary(self) -> dict:
        """The shared flat-dict view (``switchsim.results.flat_summary``)
        every result type exposes — what bench row-building reads."""
        return flat_summary(self.counters, self.telemetry,
                            peak_occupancy=self.peak_occupancy,
                            nf_counters=self.nf_counters)


@dataclasses.dataclass
class _Prepared:
    spec: ScenarioSpec
    pkts: object
    chain: object
    traces: object
    steer_stats: dict
    n_pipes: int
    faults: F.FaultArrays = None  # per-pipe masks over the steered steps


def _prepare(spec: ScenarioSpec) -> _Prepared:
    pkts = make_packets(spec)
    chain = build_chain(spec, pkts)
    traces, stats = steer(spec, pkts)
    steps = jax.tree.leaves(traces)[0].shape[1]
    fa = F.resolve(spec.fault, pipes=spec.pipes, steps=steps)
    return _Prepared(spec, pkts, chain, traces, stats, spec.pipes, fa)


def _cat_pipe_axis(traces_list):
    return jax.tree.map(
        lambda *xs: jnp.concatenate(xs, axis=0), *traces_list)


def run_matrix(specs, time_runs: bool = False,
               time_repeats: int = 1) -> list[ScenarioResult]:
    """Execute scenario points, batching trace-compatible ones.

    Returns results in the order of ``specs``.  ``time_runs`` re-executes
    each compiled group ``time_repeats`` times after warm-up and
    attributes the mean group wall time evenly across its points (a
    per-point wall clock would defeat the shared-compile batching; the
    engine-vs-loop speedup bench times the engine directly where exact
    per-run numbers matter).
    """
    prepared = [_prepare(s) for s in specs]
    groups: dict = {}
    for i, p in enumerate(prepared):
        steps = jax.tree.leaves(p.traces)[0].shape[1]
        key = compile_key(p.spec, p.chain, steps)
        groups.setdefault(key, []).append(i)

    results: list = [None] * len(prepared)
    for key, members in groups.items():
        (cfg, chain, window, _chunk, _steps, _pmax, explicit_drops,
         _lane, backend, devices) = key
        stacked = _cat_pipe_axis([prepared[i].traces for i in members])
        # fault masks ride the same stacked pipe axis as the traces —
        # healthy members contribute all-True columns, so one compiled
        # program serves faulted and healthy points alike (DESIGN.md §10)
        stacked_faults = F.concat([prepared[i].faults for i in members])

        # ``devices`` shards the group's *concatenated* pipe axis
        # (switchsim.fabric): the group stays ONE program whose shards
        # may each hold pipes from different scenario points — the
        # per-scenario regrouping below gathers across shard boundaries
        # transparently (DESIGN.md §12).
        def run(cfg=cfg, chain=chain, stacked=stacked, window=window,
                explicit_drops=explicit_drops, backend=backend,
                stacked_faults=stacked_faults, devices=devices):
            return E.run_pipes(cfg, chain, stacked, window=window,
                               explicit_drops=explicit_drops,
                               backend=backend, faults=stacked_faults,
                               devices=devices)

        res = run()
        if time_runs:
            jax.block_until_ready(res.merged.payload)
            t0 = time.perf_counter()
            for _ in range(max(time_repeats, 1)):
                timed = run()
                jax.block_until_ready(timed.merged.payload)
            group_wall = (time.perf_counter() - t0) / max(time_repeats, 1)
        else:
            group_wall = 0.0
        offset = 0
        for i in members:
            p = prepared[i]
            lo, hi = offset, offset + p.n_pipes
            offset = hi
            per_ctr = res.per_pipe_counters[lo:hi]
            per_tel = res.per_pipe_telemetry[lo:hi]
            per_nf = res.per_pipe_nf_counters[lo:hi]
            tel = sum_telemetry(per_tel)
            agg = {name: sum(c[name] for c in per_ctr) for name in C.NAMES}
            nf_agg = {name: sum(c[name] for c in per_nf)
                      for name in (per_nf[0] if per_nf else {})}
            results[i] = ScenarioResult(
                spec=p.spec,
                counters=agg,
                telemetry=tel,
                per_pipe_counters=per_ctr,
                per_pipe_telemetry=per_tel,
                per_pipe_peak_occupancy=res.per_pipe_peak_occupancy[lo:hi],
                nf_counters=nf_agg,
                per_pipe_nf_counters=per_nf,
                per_pipe_occ_series=res.per_pipe_occ_series[lo:hi],
                gain=E.goodput_gain_from_telemetry(tel),
                steer_stats=p.steer_stats,
                nf_cycles=chain.cycle_costs(backend=backend),
                wall_s=group_wall / len(members),
                group_size=len(members),
                group_wall_s=group_wall,
                prepared=p,
            )
        assert offset == len(res.per_pipe_counters)
    return results


class OracleMismatch(AssertionError):
    """Engine diverged from the host-loop reference on a scenario point."""


def verify_oracle(result: ScenarioResult, faults=True) -> None:
    """Assert engine ≡ host loop (counters + telemetry + NF counters) for
    one point.

    Re-runs ``simulate_loop`` per pipe on the pipe's flat trace (dead
    padding rows are no-ops for the loop exactly as for the engine), on
    the point's own backend (the loop dispatches the same primitives), and
    compares against the engine's per-pipe counters and telemetry.
    Raises ``OracleMismatch`` on any difference.

    ``faults`` controls whether the spec's fault event is mirrored into
    the loop (the default; the engine≡loop invariant must hold *through*
    fault events).  Pass ``faults=False`` to re-run the loop healthy —
    useful only for demonstrating that a fault actually changed behaviour.

    **Per-shard semantics** (``spec.devices`` > 1, DESIGN.md §12): the
    fabric shards the pipe axis contiguously, so the per-pipe check below
    *is* the per-shard check — each device's pipe slice is verified
    independently against its own host-loop re-run, with no cross-shard
    state to reconcile.  Mismatch messages name the shard the diverging
    pipe ran on so multi-device failures localize to a device.
    """
    spec = result.spec
    # reuse the traffic/chain/traces the result was computed from; a
    # result reconstructed without them (deserialized, hand-built) still
    # verifies via deterministic re-preparation
    p = result.prepared if result.prepared is not None else _prepare(spec)
    cfg = spec.park_config()
    from repro.core.packet import from_time_major
    # contiguous shard of each pipe index, for mismatch localization
    # (devices that didn't divide the pipe axis ran replicated on shard 0)
    per_shard = (spec.pipes // spec.devices
                 if spec.pipes % spec.devices == 0 else spec.pipes)
    for pipe in range(spec.pipes):
        shard = pipe // max(per_shard, 1)
        where = (f"{spec.name} pipe {pipe} (shard {shard}/{spec.devices})"
                 if spec.devices > 1 else f"{spec.name} pipe {pipe}")
        flat = from_time_major(jax.tree.map(lambda a: a[pipe], p.traces))
        loop = simulate_loop(cfg, p.chain, flat, window=spec.window,
                             chunk=spec.chunk,
                             explicit_drops=spec.explicit_drops,
                             backend=spec.backend_config(),
                             faults=spec.fault if faults else None,
                             fault_pipe=pipe)
        if loop.counters != result.per_pipe_counters[pipe]:
            raise OracleMismatch(
                f"{where}: counters diverged\n"
                f"  engine: {result.per_pipe_counters[pipe]}\n"
                f"  loop:   {loop.counters}")
        if loop.telemetry != result.per_pipe_telemetry[pipe]:
            raise OracleMismatch(
                f"{where}: telemetry diverged\n"
                f"  engine: {result.per_pipe_telemetry[pipe]}\n"
                f"  loop:   {loop.telemetry}")
        if loop.nf_counters != result.per_pipe_nf_counters[pipe]:
            raise OracleMismatch(
                f"{where}: NF counters diverged\n"
                f"  engine: {result.per_pipe_nf_counters[pipe]}\n"
                f"  loop:   {loop.nf_counters}")


def default_rows(result: ScenarioResult, family: str) -> list[tuple]:
    """Generic schema-v2 artifact rows for one point: the goodput headline
    plus the counters that have historically caught regressions.  Curated
    benches format their own richer rows; the nightly matrix driver
    (benchmarks/run.py) emits these."""
    s, sm = result.spec, result.summary()
    derived = (f"wire_bytes={sm['wire_bytes']};srv_bytes={sm['srv_bytes']};"
               f"ret_bytes={sm['ret_bytes']};splits={sm['splits']};"
               f"merges={sm['merges']};"
               f"premature={sm['premature_evictions']};"
               f"peak_occ={sm['peak_occupancy']};"
               f"overflow={result.steer_stats['overflow']}")
    rows = [
        (f"{family}/{s.name}/goodput_gain",
         round(result.gain["goodput_gain"], 4), derived, s.name),
        (f"{family}/{s.name}/link_byte_saving",
         round(result.gain["link_byte_saving"], 4),
         f"naive={result.gain['link_byte_saving_naive']:.4f}", s.name),
    ]
    if s.recirc:
        rows.append((
            f"{family}/{s.name}/recirculations", sm["recirculations"],
            f"budget_drops={sm['recirc_budget_drops']};"
            f"recirc_bytes={sm['tel_recirc_bytes']}", s.name))
    return rows
