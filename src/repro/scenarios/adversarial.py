"""Adversarial & failure scenario families (DESIGN.md §10).

Four sub-families registered together as the ``adversarial`` family — the
grid CI and the nightly matrix drive through the ordinary scenario runner,
plus the *graceful-degradation gates* this module computes over the
results:

  * ``exhaust_*``       — parking-table exhaustion under a SYN-flood-style
                          small-packet storm (``traffic.adversarial``):
                          attack packets are 208 B — just over the §5 park
                          threshold — so every attack packet claims a
                          parked slot for a 166 B payload.  Swept over
                          attack fraction x burst length against a
                          half-in-flight table with max_exp=1; the gate
                          bounds the wire-level drop rate and requires it
                          to grow *monotonically* with attack load (the
                          permutation-rank coupling in the workload makes
                          higher fractions strict supersets).
  * ``churn_*``         — NAT CLOCK-aging under sustained flow churn
                          (``traffic.churn``): a half-overlapping sliding
                          flow window twice the NAT table size, so old
                          bindings age out while their flows still send.
                          The gate requires the ``nat_stale_hits`` counter
                          to fire (the §10 stale-mapping rule) and bounds
                          the resulting drop rate.
  * ``lb_kill_recover`` — Maglev backend 3 dies for a quarter of the trace
                          and comes back (``FaultSpec(kind="lb")``).  The
                          LB remaps via the pre-built degraded table; no
                          packet is lost, so the gate pins the drop rate
                          at (near) zero and requires a clean table at end
                          of trace.
  * ``failover_*``      — the NF server behind pipe 0 dies for a quarter
                          of the per-pipe trace (``FaultSpec(kind=
                          "server")``), in both failover modes: ``drain``
                          (the failover agent emits OP=drop notifications;
                          parked payloads of lost packets are freed at
                          Merge — the gate requires ZERO leaked slots) and
                          ``drop`` (slots leak until ring-eviction
                          reclaims them — the gate bounds the recovery
                          time instead).

Every gate is emitted into the artifact's ``degradation`` block
(benchmarks/artifacts.py) and enforced by benchmarks/compare.py: a false
``ok`` flag fails the comparison like a tolerance breach, and gates
present in the committed baseline may not disappear from a candidate.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs import sweeps
from repro.scenarios.registry import register
from repro.scenarios.runner import ScenarioResult
from repro.scenarios.spec import ScenarioSpec
from repro.switchsim.faults import FaultSpec
from repro.traffic.generator import pipe_trace_steps

EXHAUST_FRACS = (0.0, 0.25, 0.75)


@register("adversarial")
def adversarial_family(tiny: bool) -> list[ScenarioSpec]:
    sh = sweeps.shape(tiny)
    inflight = sh.window * sh.chunk
    specs: list[ScenarioSpec] = []

    # (a) parking-table exhaustion: storm vs a half-in-flight table.
    # MacSwap never drops at the NF, so every lost packet is a premature
    # eviction — the drop rate isolates the parking table's degradation.
    # max_exp=2: one expiry grace period — the healthy baseline stays
    # under ~6% loss while the storm degrades to ~35% (graceful, bounded),
    # instead of the whole mix thrashing at max_exp=1
    exhaust = ScenarioSpec(
        name="", chain=("macswap",), capacity=inflight // 2, max_exp=2,
        packets=sh.packets, chunk=sh.chunk, window=sh.window, pmax=sh.pmax)
    for burst in (8,) if tiny else (8, 64):
        for frac in EXHAUST_FRACS:
            specs.append(dataclasses.replace(
                exhaust, name=f"exhaust_f{int(frac * 100):02d}_b{burst}",
                workload=("adversarial", "enterprise", frac, burst)))

    # (b) NAT CLOCK-aging churn: live-flow window = 2x the NAT table.
    # explicit_drops frees the parked slots of NAT-dropped packets
    # (exhausted inserts + stale hits), so a clean end-of-trace table is
    # part of the gate here too.
    nat_cap = 64 if tiny else 256
    churn = ScenarioSpec(
        name="", chain=("nat",), capacity=2 * inflight, max_exp=2,
        packets=sh.packets, chunk=sh.chunk, window=sh.window, pmax=sh.pmax,
        nat_capacity=nat_cap, explicit_drops=True)
    for label, div in (("slow", 4), ("fast", 16)):
        specs.append(dataclasses.replace(
            churn, name=f"churn_{label}",
            workload=("churn", 2 * nat_cap, sh.packets // div)))

    # (c) Maglev backend kill -> recover mid-trace (global LB fault).
    steps = sh.steps
    # explicit_drops: firewall/NAT-dropped packets free their parked slots
    # (§6.2.4), so the clean-table gate isolates what the LB fault leaks
    specs.append(ScenarioSpec(
        name="lb_kill_recover", chain=("fw", "nat", "lb"),
        capacity=4 * inflight, max_exp=4, packets=sh.packets,
        chunk=sh.chunk, window=sh.window, pmax=sh.pmax,
        flows=256 if tiny else 1024, fw_rules=20, explicit_drops=True,
        fault=FaultSpec(kind="lb", start=steps // 4,
                        duration=steps // 4, backend=3)))

    # (d) NF-server failover on pipe 0 of 2, drain vs drop semantics.
    # capacity = 2x in-flight leaves headroom so the fault's slot bump is
    # visible in the occupancy series (the recovery gate's signal).
    psteps = pipe_trace_steps(sh.packets, 2, sh.chunk)
    failover = ScenarioSpec(
        name="", chain=("fw", "nat"), pipes=2, capacity=2 * inflight,
        max_exp=1, packets=sh.packets, chunk=sh.chunk, window=sh.window,
        pmax=sh.pmax, explicit_drops=True)
    for mode, drain in (("drain", True), ("drop", False)):
        specs.append(dataclasses.replace(
            failover, name=f"failover_{mode}",
            fault=FaultSpec(kind="server", start=psteps // 4,
                            duration=psteps // 4, pipe=0, drain=drain)))
    return specs


# ---------------------------------------------------------------------------
# Graceful-degradation metrics and gates (DESIGN.md §10).


def degradation_metrics(result: ScenarioResult) -> dict:
    """The §10 degradation quantities for one executed scenario point.

    * ``drop_rate``      — wire-level packet loss, 1 - merged/offered
                           (premature evictions + NF drops + fault drops);
    * ``occ_peak``       — peak parked-slot occupancy across pipes;
    * ``occ_final``      — parked slots still live after the drain window
                           (leaked slots: nothing in flight can free them);
    * ``fault_drops``    — packets lost at a down NF server;
    * ``nat_stale_hits`` — stale-mapping hits (NAT chains only);
    * ``recovery_steps`` — server faults only: steps after the fault ends
                           until the victim pipe's occupancy returns to
                           its pre-fault level (-1 = never recovered).
    """
    tel, c = result.telemetry, result.counters
    occ = np.asarray(result.per_pipe_occ_series)
    m = dict(
        drop_rate=round(1.0 - tel.merged_pkts / max(tel.wire_pkts, 1), 6),
        occ_peak=int(result.peak_occupancy),
        occ_final=int(occ[:, -1].sum()),
        fault_drops=int(c["fault_drops"]),
    )
    if "nat_stale_hits" in result.nf_counters:
        m["nat_stale_hits"] = int(result.nf_counters["nat_stale_hits"])
    fault = result.spec.fault
    if fault.active and fault.kind == "server":
        series = occ[fault.pipe]
        baseline = int(series[fault.start - 1]) if fault.start else 0
        after = series[fault.end:]
        hits = np.nonzero(after <= baseline)[0]
        m["recovery_steps"] = int(hits[0]) if hits.size else -1
    return m


# Per-sub-family gate tables: metric -> (op, bound).  A bound may also be
# the *name* of another metric (e.g. the drop-mode leak gate ``occ_final
# <= fault_drops``: leaked slots must be attributable to killed packets).
# Bounds are loose envelopes around the committed-baseline behaviour —
# they catch a family falling off a cliff (leaks, unbounded loss, no
# recovery), not 1% noise (that is compare.py's tolerance job).
_OPS = {
    "<=": lambda v, b: v <= b,
    ">=": lambda v, b: v >= b,
    "==": lambda v, b: v == b,
}


def bounds_for(spec: ScenarioSpec) -> dict[str, tuple[str, object]]:
    """Graceful-degradation gate for one scenario point."""
    name = spec.name
    if name.startswith("exhaust_"):
        frac = float(spec.workload[2])
        # losses are premature evictions only; measured healthy baseline
        # is ~5-6% at both geometries, the storm adds at most ~0.4x its
        # attack share on top (tiny/full sweep in the PR that added this)
        return {"drop_rate": ("<=", round(0.12 + 0.5 * frac, 4)),
                "occ_peak": ("<=", spec.capacity),
                "occ_final": ("==", 0)}
    if name.startswith("churn_"):
        return {"drop_rate": ("<=", 0.60),
                "nat_stale_hits": (">=", 1),
                "occ_peak": ("<=", spec.capacity),
                "occ_final": ("==", 0)}
    if name == "lb_kill_recover":
        # the firewall blocks fw_rules of the flow pool by design; the LB
        # fault itself must not add packet loss beyond that floor
        fw_floor = spec.fw_rules / max(spec.flows, 1)
        return {"drop_rate": ("<=", round(fw_floor + 0.06, 4)),
                "fault_drops": ("==", 0),
                "occ_peak": ("<=", spec.capacity),
                "occ_final": ("==", 0)}
    if name.startswith("failover_"):
        gates = {
            # one pipe dark for a quarter of its trace loses at most that
            # share of the offered load (plus steering imbalance slack)
            "drop_rate": ("<=", 0.25),
            "occ_peak": ("<=", spec.pipes * spec.capacity),
        }
        if spec.fault.drain:
            # THE drain invariant: OP=drop notifications free every
            # parked slot a killed packet left behind, and the victim
            # pipe's occupancy settles back to its pre-fault level within
            # a couple of in-flight windows (measured: 3 tiny, 6 full)
            gates["occ_final"] = ("==", 0)
            gates["recovery_steps"] = ("<=", 2 * spec.window + 4)
        else:
            # drop mode leaks until ring eviction reclaims the slots —
            # bounded leak: every leaked slot belongs to a killed packet
            gates["occ_final"] = ("<=", "fault_drops")
        return gates
    raise ValueError(f"no degradation gate defined for {name!r}")


def degradation_block(results: list[ScenarioResult]) -> dict:
    """Artifact ``degradation`` block: per-scenario metrics + gate verdicts.

    ``ok`` at the top level is the AND of every gate; compare.py fails a
    candidate artifact whose block carries any false gate, and requires
    every gate present in the committed baseline to still exist.
    """
    scenarios = {}
    all_ok = True
    for r in results:
        metrics = degradation_metrics(r)
        gates = []
        for metric, (op, bound) in bounds_for(r.spec).items():
            if metric not in metrics:
                raise ValueError(
                    f"{r.spec.name}: gated metric {metric!r} not computed")
            limit = metrics[bound] if isinstance(bound, str) else bound
            ok = bool(_OPS[op](metrics[metric], limit))
            all_ok &= ok
            gates.append(dict(metric=metric, op=op, bound=bound,
                              value=metrics[metric], ok=ok))
        scenarios[r.spec.name] = dict(metrics=metrics, gates=gates)
    return dict(ok=all_ok, scenarios=scenarios)
