"""Declarative scenario specs and the grid expander (DESIGN.md §8).

A ``ScenarioSpec`` names one point of the evaluation matrix: workload x
NF chain x recirculation mode x pipes x table occupancy x trace geometry.
It is a frozen, hashable value — no arrays, no callables — so specs can be
grouped, deduplicated, serialized into BENCH_*.json artifacts, and used as
compile-cache keys.  Everything runnable (packets, chains, ParkConfigs) is
*derived* from the spec by pure functions in this module; the sweep runner
(repro.scenarios.runner) is the only place that executes anything.

Workloads are named tuples (``("fixed", 512)``, ``("enterprise",)``,
``("datacenter",)``) resolved via ``resolve_workload``.  Chains are tuples
of NF names (``("fw", "nat", "lb")``) resolved via ``build_chain``; the
firewall's blocked list is drawn from the deterministic flow pool when the
spec constrains flows (``flows > 0``), which makes the chain — and hence
the compiled engine — identical across workload axes.
"""
from __future__ import annotations

import dataclasses
import itertools

import jax
import numpy as np

from repro.backend import BackendConfig, as_config
from repro.core.park import ParkConfig
from repro.core.packet import PacketBatch, to_time_major
from repro.nf.chain import Chain
from repro.nf.firewall import Firewall
from repro.nf.macswap import MacSwap
from repro.nf.maglev import MaglevLB
from repro.nf.nat import Nat
from repro.switchsim.faults import NO_FAULT, FaultSpec
from repro.traffic import generator as T

# ("fixed", size) | ("enterprise",) | ("datacenter",)
# | ("adversarial", base, attack_fraction, burst)   (DESIGN.md §10)
# | ("churn", pool, rotate)
WorkloadSpec = tuple
ChainSpec = tuple     # e.g. ("fw", "nat", "lb"); names below


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One declarative point of the evaluation matrix.

    ``name`` is the point's identity inside its family; artifact rows are
    emitted as ``<family>/<name>/<metric>``.  ``flows`` > 0 constrains
    ``src_ip`` to a deterministic ``flows``-IP pool (flow structure for
    NAT/LB plus a workload-independent firewall rule set); 0 keeps the
    seed benches' behaviour (random IPs, rules drawn from the traffic).
    ``backend`` names the dataplane-backend the point runs on
    (``repro.backend``: "ref" | "pallas" | "pallas_interpret" | "auto") —
    a first-class grid axis, so ref-vs-Pallas sweeps ride the same runner
    as every other comparison (DESIGN.md §9).

    ``fault`` injects one fault event (``switchsim.faults.FaultSpec``,
    DESIGN.md §10); fault *timing* is data, so faulted and healthy points
    still share a compile group.  ``nat_capacity`` overrides the NAT
    flow-table size (0 = the NF's default) — the churn family shrinks it
    below the live flow window to sustain CLOCK aging.

    ``devices`` shards the point's flat pipe axis over that many devices
    (``switchsim.fabric``, DESIGN.md §12) — a first-class grid axis and
    part of the compile key, since a sharded program is a different XLA
    program even at equal shapes.  Results are device-count invariant
    (bit-identical counters/telemetry/occupancy), so scaling sweeps vary
    only wall-clock.
    """

    name: str
    workload: WorkloadSpec = ("enterprise",)
    chain: ChainSpec = ("fw", "nat")
    pipes: int = 1
    recirc: bool = False
    recirc_frac: float = 0.25
    capacity: int = 4096
    max_exp: int = 2
    packets: int = 16384
    chunk: int = 256
    window: int = 2
    pmax: int = 2048
    explicit_drops: bool = False
    seed: int = 0
    flows: int = 0
    fw_rules: int = 20
    backend: str = "auto"
    fault: FaultSpec = NO_FAULT
    nat_capacity: int = 0
    devices: int = 1

    def __post_init__(self):
        as_config(self.backend)  # validates the backend name eagerly
        if self.packets % self.chunk:
            raise ValueError(
                f"{self.name}: packets ({self.packets}) must be a multiple "
                f"of chunk ({self.chunk})")
        if self.pipes < 1:
            raise ValueError(f"{self.name}: pipes must be >= 1")
        if self.devices < 1:
            raise ValueError(f"{self.name}: devices must be >= 1")
        resolve_workload(self.workload)  # validates the name eagerly
        for nf in self.chain:
            if nf not in _NF_NAMES:
                raise ValueError(
                    f"{self.name}: unknown NF {nf!r} (have {_NF_NAMES})")
        if self.flows and "fw" in self.chain and self.fw_rules >= self.flows:
            raise ValueError(
                f"{self.name}: fw_rules ({self.fw_rules}) must be < flows "
                f"({self.flows}) — blocking the whole pool drops 100% of "
                f"the traffic")
        if self.flows and self.workload[0] in ("adversarial", "churn"):
            raise ValueError(
                f"{self.name}: workload {self.workload[0]!r} owns the "
                f"source identity (spoofed/churning flows); flows must be 0")
        if self.nat_capacity and "nat" not in self.chain:
            raise ValueError(
                f"{self.name}: nat_capacity set but no 'nat' in chain")
        f = self.fault
        if f.active:
            steps = T.pipe_trace_steps(self.packets, self.pipes, self.chunk)
            if f.end > steps:
                raise ValueError(
                    f"{self.name}: fault window [{f.start}, {f.end}) "
                    f"exceeds the {steps}-step per-pipe trace — faults "
                    f"must live within the offered traffic")
            if f.kind == "server" and f.pipe >= self.pipes:
                raise ValueError(
                    f"{self.name}: fault pipe {f.pipe} >= pipes "
                    f"({self.pipes})")
            if f.kind == "lb" and "lb" not in self.chain:
                raise ValueError(
                    f"{self.name}: lb fault but no 'lb' in chain")

    def park_config(self) -> ParkConfig:
        return ParkConfig(capacity=self.capacity, max_exp=self.max_exp,
                          pmax=self.pmax, recirculation=self.recirc,
                          recirc_frac=self.recirc_frac)

    def backend_config(self) -> BackendConfig:
        """Concrete (platform-resolved) backend selection: "auto" and its
        resolution share one compile group on any given host."""
        return as_config(self.backend).concrete()

    def as_dict(self) -> dict:
        """JSON-ready form for the schema-v2 artifact ``matrix`` block."""
        d = dataclasses.asdict(self)
        d["workload"] = list(self.workload)
        d["chain"] = list(self.chain)
        return d


def resolve_workload(ws: WorkloadSpec) -> T.Workload:
    """Workload-spec tuple -> traffic.generator.Workload."""
    kind = ws[0]
    if kind == "fixed":
        return T.fixed(int(ws[1]))
    if kind == "enterprise":
        return T.enterprise()
    if kind == "datacenter":
        return T.datacenter()
    if kind == "adversarial":
        return T.adversarial(base=ws[1], attack_fraction=float(ws[2]),
                             burst=int(ws[3]))
    if kind == "churn":
        return T.churn(pool=int(ws[1]), rotate=int(ws[2]))
    raise ValueError(f"unknown workload spec {ws!r}")


def make_packets(spec: ScenarioSpec) -> PacketBatch:
    """Deterministic traffic for one scenario point.

    The PRNG key folds in only ``seed`` — two specs with equal
    (workload, packets, pmax, flows, seed) produce bit-identical traffic
    no matter how the rest of the grid differs, so recirc-on/off pairs
    compare the same packets.
    """
    wl = resolve_workload(spec.workload)
    key = jax.random.key(spec.seed)
    pkts = wl.make_batch(key, spec.packets, pmax=spec.pmax)
    if spec.flows:
        ips, ports = T.flow_pool(spec.flows)
        idx = jax.random.randint(jax.random.fold_in(key, 1),
                                 (spec.packets,), 0, spec.flows)
        # both halves of the NAT flow key (src_ip, src_port) come from the
        # pool, so repeat flows actually repeat at the NF chain
        pkts = pkts.replace(src_ip=ips[idx], src_port=ports[idx])
    return pkts


def firewall_rules(spec: ScenarioSpec, pkts: PacketBatch) -> tuple[int, ...]:
    """Blocked-IP list: from the flow pool when flows are constrained
    (workload-independent -> chains shareable across workload axes),
    otherwise the seed benches' rule source (first N unique src IPs)."""
    if spec.flows:
        ips, _ = T.flow_pool(spec.flows)
        return tuple(int(ip) for ip in
                     np.asarray(ips[:spec.fw_rules]).tolist())
    return tuple(int(ip) for ip in
                 np.unique(np.asarray(pkts.src_ip))[:spec.fw_rules].tolist())


_NF_NAMES = ("fw", "nat", "lb", "macswap")


def build_chain(spec: ScenarioSpec, pkts: PacketBatch) -> Chain:
    """Chain-spec tuple -> runnable (and hashable) nf.chain.Chain."""
    nfs = []
    for nf in spec.chain:
        if nf == "fw":
            nfs.append(Firewall(rules=firewall_rules(spec, pkts)))
        elif nf == "nat":
            nfs.append(Nat(capacity=spec.nat_capacity) if spec.nat_capacity
                       else Nat())
        elif nf == "lb":
            nfs.append(MaglevLB(fault_target=spec.fault.backend
                                if spec.fault.kind == "lb" else -1))
        elif nf == "macswap":
            nfs.append(MacSwap())
    return Chain(tuple(nfs))


def steer(spec: ScenarioSpec, pkts: PacketBatch):
    """Shard a scenario's traffic into its (P, T, chunk, ...) traces.

    Single-pipe scenarios skip hashing entirely (identity + tail padding
    via ``to_time_major``); multi-pipe scenarios go through the §6.3.2
    flow steering.  Returns ``(traces, steer_stats)``.
    """
    if spec.pipes == 1:
        trace = to_time_major(pkts, spec.chunk)
        traces = jax.tree.map(lambda a: a[None], trace)
        stats = dict(per_pipe_arrivals=[spec.packets], overflow=0,
                     pipe_capacity=spec.packets)
        return traces, stats
    shards, stats = T.steer_pipes(pkts, spec.pipes, chunk=spec.chunk)
    traces = jax.tree.map(
        lambda a: a.reshape((spec.pipes, a.shape[1] // spec.chunk,
                             spec.chunk) + a.shape[2:]), shards)
    return traces, stats


def grid(base: ScenarioSpec, name_fmt: str, **axes) -> list[ScenarioSpec]:
    """Expand a cartesian grid of spec fields around ``base``.

    ``axes`` maps field names to value lists; ``name_fmt`` is formatted
    with each point's axis values (e.g. ``grid(base, "occ_{capacity}",
    capacity=[256, 512])``).  Axis order follows keyword order, so row
    ordering in artifacts is stable.
    """
    for field in axes:
        if field not in {f.name for f in dataclasses.fields(ScenarioSpec)}:
            raise ValueError(f"unknown grid axis {field!r}")
    specs = []
    names = list(axes.keys())
    for values in itertools.product(*axes.values()):
        kw = dict(zip(names, values))
        specs.append(dataclasses.replace(
            base, name=name_fmt.format(**kw), **kw))
    if len({s.name for s in specs}) != len(specs):
        raise ValueError(f"name_fmt {name_fmt!r} does not separate the grid")
    return specs


def compile_key(spec: ScenarioSpec, chain: Chain, steps: int):
    """Trace-compatibility signature (DESIGN.md §8).

    Two scenario points sharing this key run the *same* XLA program on
    stacked pipe traces: equal ParkConfig (capacity/max_exp/recirc mode and
    fraction -> equal state shapes and lane width), equal chain constants,
    equal trace geometry (``steps`` is taken from the point's actual
    steered traces, so per-pipe capacity rounding is reflected exactly),
    and the same concrete backend selection (a ref point and a Pallas
    point are different XLA programs even at equal shapes).  ``devices``
    is part of the key for the same reason: a shard_mapped program is a
    different XLA program, and a compile group spanning devices must stay
    ONE program whose concatenated pipe axis shards as a whole.  Points
    that differ only in workload, seed or flow structure batch together;
    shape-changing axes (occupancy/capacity, recirc_frac, chunk, window)
    fall back to the engine's lru_cache-keyed per-point loop.
    """
    from repro.switchsim import engine as E
    cfg = spec.park_config()
    lane = E.recirc_slots(cfg, spec.chunk)
    return (cfg, chain, spec.window, spec.chunk, steps, spec.pmax,
            spec.explicit_drops, lane, spec.backend_config(), spec.devices)
