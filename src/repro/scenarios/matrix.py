"""The paper's evaluation grid as first-class scenario families.

Registered families (one BENCH_<family>.json artifact each):

  * ``pipeline``          — FW->NAT on enterprise traffic across 1/2/4/8
                            per-port pipes (§6.3.2; bench_pipeline's sweep);
  * ``recirc``            — table-occupancy sweep, recirculation lane
                            off vs on (§6.2.5 / Fig. 13 direction);
  * ``hostmodel_sizes``   — MacSwap on fixed 256..1492 B + enterprise
                            (PCIe band, abstract's 2-58 %);
  * ``hostmodel_servers`` — FW->NAT on 1..8 NF servers with §6.2.3
                            lookup-table slicing;
  * ``chain``             — the §7 headline: FW->NAT->LB (Maglev) on
                            datacenter-characteristic traffic, parking
                            vs parking+recirculation (13 % -> 28 % shape),
                            with the enterprise mix alongside for contrast.

Every factory takes ``tiny`` and derives its trace geometry from
``repro.configs.sweeps`` so CI smokes and the nightly full matrix are the
same scenarios at two sizes.
"""
from __future__ import annotations

import dataclasses

from repro.configs import sweeps
from repro.scenarios.registry import register
from repro.scenarios.spec import ScenarioSpec, grid

# §7 chain scenarios constrain src IPs to a deterministic flow pool: the
# firewall's blocked list comes from the pool (not from the traffic), so
# datacenter and enterprise points share one compiled engine per mode.
CHAIN_FLOWS = dict(full=1024, tiny=256)


def _base(tiny: bool, **kw) -> ScenarioSpec:
    sh = sweeps.shape(tiny)
    kw.setdefault("packets", sh.packets)
    kw.setdefault("chunk", sh.chunk)
    kw.setdefault("window", sh.window)
    kw.setdefault("pmax", sh.pmax)
    return ScenarioSpec(**kw)


def pipeline_grid(pipes_list, *, packets, chunk, window, pmax, capacity,
                  explicit_drops: bool = False,
                  backends=("ref",), devices=(1,)) -> list[ScenarioSpec]:
    """The pipes sweep at explicit geometry — the ONE definition of the
    §6.3.2 grid; ``pipeline_family`` and ``bench_pipeline``'s CLI both
    delegate here so the two can never drift apart.

    ``backends`` adds the dataplane-backend axis (DESIGN.md §9) and
    ``devices`` the fabric-sharding axis (DESIGN.md §12;
    ``bench_pipeline --devices``).  Single-valued axes keep the historical
    point names (``pipes2``) so committed artifact baselines keep matching
    regardless of which backend/device count produced them; multi-valued
    axes separate the points by name (``pipes2_pallas_interpret``,
    ``pipes2_dev4``) so one artifact records the sweep side by side."""
    base = ScenarioSpec(
        name="", workload=("enterprise",), chain=("fw", "nat"),
        capacity=capacity, max_exp=2, packets=packets, chunk=chunk,
        window=window, pmax=pmax, explicit_drops=explicit_drops)
    backends = list(backends)
    devices = list(devices)
    name, axes = "pipes{pipes}", dict(pipes=list(pipes_list))
    if len(backends) == 1:
        base = dataclasses.replace(base, backend=backends[0])
    else:
        name, axes["backend"] = name + "_{backend}", backends
    if len(devices) == 1:
        base = dataclasses.replace(base, devices=devices[0])
    else:
        name, axes["devices"] = name + "_dev{devices}", devices
    return grid(base, name, **axes)


@register("pipeline")
def pipeline_family(tiny: bool) -> list[ScenarioSpec]:
    sh = sweeps.shape(tiny)
    return pipeline_grid([1, 2] if tiny else [1, 2, 4, 8],
                         packets=sh.packets, chunk=sh.chunk,
                         window=sh.window, pmax=sh.pmax,
                         capacity=256 if tiny else 4096)


def recirc_grid(*, packets, chunk, window, pmax,
                recirc_frac: float = 0.25) -> list[ScenarioSpec]:
    """The §6.2.5 occupancy x lane-mode sweep at explicit geometry — the
    ONE definition of the grid (capacity points are multiples of the
    in-flight window); ``recirc_family`` and ``bench_pipeline --recirc``
    both delegate here.

    max_exp=4 keeps the full table out of the premature-eviction regime
    (occupancy pressure, not eviction losses, is the §6.2.5 experiment).
    """
    inflight = max(window, 1) * chunk
    base = ScenarioSpec(
        name="", workload=("enterprise",), chain=("fw", "nat", "lb"),
        max_exp=4, packets=packets, chunk=chunk, window=window, pmax=pmax,
        recirc_frac=recirc_frac)
    specs = []
    for label, capacity in (("low", 8 * inflight), ("mid", inflight),
                            ("high", inflight // 2)):
        for mode, on in (("off", False), ("on", True)):
            specs.append(dataclasses.replace(
                base, name=f"occ_{label}_{mode}", capacity=capacity,
                recirc=on))
    return specs


@register("recirc")
def recirc_family(tiny: bool) -> list[ScenarioSpec]:
    sh = sweeps.shape(tiny)
    return recirc_grid(packets=sh.packets, chunk=sh.chunk,
                       window=sh.window, pmax=sh.pmax)


@register("hostmodel_sizes")
def hostmodel_sizes_family(tiny: bool) -> list[ScenarioSpec]:
    sizes = [256, 1492] if tiny else [256, 384, 512, 1024, 1492]
    # pmax=2048 even in tiny mode: the size sweep reaches 1492 B packets
    # and the historical artifact rows were produced with full buffers
    base = _base(tiny, name="", chain=("macswap",), pmax=2048,
                 capacity=512 if tiny else 4096, max_exp=2)
    specs = [dataclasses.replace(base, name=f"fixed{s}",
                                 workload=("fixed", s), seed=i)
             for i, s in enumerate(sizes)]
    specs.append(dataclasses.replace(base, name="enterprise",
                                     workload=("enterprise",),
                                     seed=len(sizes)))
    return specs


@register("hostmodel_servers")
def hostmodel_servers_family(tiny: bool, mem_frac: float = 0.40,
                             ) -> list[ScenarioSpec]:
    from repro.core.park import ParkConfig
    from repro.hostmodel import per_server_capacity
    base = _base(tiny, name="", workload=("enterprise",),
                 chain=("fw", "nat"), pmax=2048, max_exp=2, seed=99)
    specs = []
    for n in [1, 2] if tiny else [1, 2, 4, 8]:
        capacity = per_server_capacity(
            mem_frac, ParkConfig(pmax=base.pmax), n)
        specs.append(dataclasses.replace(
            base, name=f"servers{n}", pipes=n, capacity=capacity))
    return specs


@register("chain")
def chain_family(tiny: bool) -> list[ScenarioSpec]:
    flows = CHAIN_FLOWS["tiny" if tiny else "full"]
    # max_exp=4 for the same reason as the recirc family: the §7 claim is
    # about parked-byte savings, not eviction-loss dynamics
    base = _base(tiny, name="", chain=("fw", "nat", "lb"),
                 capacity=256 if tiny else 4096, max_exp=4,
                 flows=flows, fw_rules=20)
    specs = []
    for wl in ("datacenter", "enterprise"):
        for mode, on in (("base", False), ("recirc", True)):
            specs.append(dataclasses.replace(
                base, name=f"{wl}_{mode}", workload=(wl,), recirc=on))
    return specs
