"""Named scenario families: the registry the matrix driver and CI iterate.

A *family* is a named factory ``(tiny: bool) -> list[ScenarioSpec]`` — one
BENCH_<family>.json artifact per family.  Families are registered at import
time by ``repro.scenarios.matrix`` (the paper's evaluation grid); ad-hoc
experiments can register their own without touching the shipped matrix.
"""
from __future__ import annotations

from typing import Callable

from repro.scenarios.spec import ScenarioSpec

_FAMILIES: dict[str, Callable[[bool], list[ScenarioSpec]]] = {}


def register(name: str):
    """Decorator: register a scenario-family factory under ``name``."""
    def deco(factory: Callable[[bool], list[ScenarioSpec]]):
        if name in _FAMILIES:
            raise ValueError(f"scenario family {name!r} already registered")
        _FAMILIES[name] = factory
        return factory
    return deco


def family(name: str, tiny: bool = False) -> list[ScenarioSpec]:
    """Expand one registered family; raises KeyError with the known names."""
    if name not in _FAMILIES:
        raise KeyError(
            f"unknown scenario family {name!r}; registered: {names()}")
    specs = _FAMILIES[name](tiny)
    seen = [s.name for s in specs]
    if len(set(seen)) != len(seen):
        raise ValueError(f"family {name!r} has duplicate scenario names")
    return specs


def names() -> list[str]:
    return sorted(_FAMILIES)
