"""Suppression baseline: the only sanctioned way to silence a finding.

There are deliberately no inline ``# replint: ignore`` pragmas — every
exemption lives in one committed JSON file (``replint_baseline.json``)
where review can see it, diff it, and count it.  Policy (DESIGN.md §11):
the baseline may SHRINK, never GROW; CI pins the entry count and the
budget only ever gets lowered.

Entries match findings by fingerprint (rule | path | source-line text),
so they survive line-number drift but expire when the suppressed line
itself changes.  An entry that matches nothing is *stale* and fails the
run: a fixed violation must leave the baseline in the same PR.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

from repro.analysis.core import Finding

DEFAULT_BASELINE = "replint_baseline.json"


@dataclasses.dataclass(frozen=True)
class BaselineEntry:
    fingerprint: str
    rule: str
    path: str
    justification: str   # required, human-written — why this is exempt

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Baseline:
    path: Path | None
    entries: list[BaselineEntry]

    def __len__(self) -> int:
        return len(self.entries)

    def split(self, findings: list[Finding]
              ) -> tuple[list[Finding], list[Finding], list[BaselineEntry]]:
        """Partition ``findings`` into (unsuppressed, suppressed) and
        return the stale baseline entries that matched nothing."""
        by_fp = {e.fingerprint: e for e in self.entries}
        unsuppressed, suppressed = [], []
        hit: set[str] = set()
        for f in findings:
            entry = by_fp.get(f.fingerprint)
            if entry is not None:
                suppressed.append(f)
                hit.add(entry.fingerprint)
            else:
                unsuppressed.append(f)
        stale = [e for e in self.entries if e.fingerprint not in hit]
        return unsuppressed, suppressed, stale


def load_baseline(path: str | Path | None) -> Baseline:
    """Load the baseline; a missing file is an empty baseline (new trees
    start clean), but a malformed one is an error — silence must never be
    the result of a parse failure."""
    if path is None:
        return Baseline(path=None, entries=[])
    p = Path(path)
    if not p.exists():
        return Baseline(path=p, entries=[])
    data = json.loads(p.read_text(encoding="utf-8"))
    entries = []
    for raw in data.get("suppressions", []):
        missing = {"fingerprint", "rule", "path", "justification"} - set(raw)
        if missing:
            raise ValueError(
                f"{p}: baseline entry {raw!r} missing keys {sorted(missing)}")
        if not str(raw["justification"]).strip():
            raise ValueError(
                f"{p}: baseline entry for {raw['path']} ({raw['rule']}) has "
                "an empty justification — every exemption must say why")
        entries.append(BaselineEntry(
            fingerprint=raw["fingerprint"], rule=raw["rule"],
            path=raw["path"], justification=raw["justification"]))
    return Baseline(path=p, entries=entries)


def render_baseline(findings: list[Finding], note: str = "") -> str:
    """Serialise findings as a fresh baseline skeleton (``--write-baseline``).
    Justifications are emitted empty ON PURPOSE: loading rejects them, so
    a generated baseline cannot be committed without a human writing the
    why for every entry."""
    return json.dumps({
        "_policy": "shrink-only: entries may be removed, never added without "
                   "review; CI pins the count (see ci.yml lint job)",
        "_note": note,
        "suppressions": [
            {"fingerprint": f.fingerprint, "rule": f.rule, "path": f.path,
             "line": f.line, "message": f.message, "justification": ""}
            for f in findings],
    }, indent=2) + "\n"
