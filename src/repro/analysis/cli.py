"""Command line for replint: ``python -m repro.analysis``.

Exit codes: 0 clean (all findings baseline-suppressed or none), 1 any
unsuppressed finding OR stale baseline entry (a fixed violation must leave
the baseline in the same PR), 2 usage/environment error.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

from repro.analysis.baseline import (DEFAULT_BASELINE, load_baseline,
                                     render_baseline)
from repro.analysis.core import analyze, load_project
from repro.analysis.rules import ALL_RULES


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="replint: PayloadPark-repro invariant lint "
                    "(RPL001-RPL007, DESIGN.md §11)")
    p.add_argument("paths", nargs="*", default=["src"],
                   help="files/directories to analyze (default: src)")
    p.add_argument("--json", metavar="FILE",
                   help="write findings + baseline accounting as JSON")
    p.add_argument("--baseline", metavar="FILE", default=DEFAULT_BASELINE,
                   help=f"suppression baseline (default: {DEFAULT_BASELINE})")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore the baseline: report every finding")
    p.add_argument("--write-baseline", metavar="FILE",
                   help="write current findings as a baseline skeleton "
                        "(justifications left empty on purpose) and exit")
    p.add_argument("--changed-only", nargs="?", const="HEAD",
                   metavar="GIT_BASE",
                   help="only analyze .py files changed vs GIT_BASE "
                        "(default HEAD); cross-file rules still load their "
                        "counterpart files")
    p.add_argument("--select", metavar="IDS",
                   help="comma-separated rule ids to run (e.g. "
                        "RPL001,RPL003)")
    p.add_argument("--list-rules", action="store_true",
                   help="list rule ids and titles, then exit")
    return p


def _changed_files(base: str, scope: list[str]) -> list[str] | None:
    """Changed .py files vs ``base`` that live under one of ``scope``."""
    try:
        out = subprocess.run(
            ["git", "diff", "--name-only", "--diff-filter=d", base,
             "--", "*.py"],
            capture_output=True, text=True, check=True).stdout
    except (OSError, subprocess.CalledProcessError) as e:
        print(f"replint: --changed-only: git diff failed: {e}",
              file=sys.stderr)
        return None
    scope_paths = [Path(s).resolve() for s in scope]
    picked = []
    for line in out.splitlines():
        p = Path(line.strip())
        if not p.exists():
            continue
        rp = p.resolve()
        if any(rp == s or s in rp.parents for s in scope_paths):
            picked.append(str(p))
    return picked


def main(argv: list[str] | None = None) -> int:
    args = _parser().parse_args(argv)

    rules = list(ALL_RULES)
    if args.select:
        wanted = {s.strip() for s in args.select.split(",") if s.strip()}
        unknown = wanted - {r.rule_id for r in ALL_RULES}
        if unknown:
            print(f"replint: unknown rule ids: {sorted(unknown)}",
                  file=sys.stderr)
            return 2
        rules = [r for r in rules if r.rule_id in wanted]

    if args.list_rules:
        for r in ALL_RULES:
            print(f"{r.rule_id}  {r.title}")
        return 0

    paths = list(args.paths) or ["src"]
    if args.changed_only:
        changed = _changed_files(args.changed_only, paths)
        if changed is None:
            return 2
        if not changed:
            print("replint: no changed .py files in scope — clean")
            if args.json:
                Path(args.json).write_text(json.dumps(
                    {"findings": [], "suppressed": [], "stale_baseline": [],
                     "baseline_count": 0, "files_analyzed": 0}, indent=2))
            return 0
        paths = changed

    project = load_project(paths)
    findings = analyze(project, rules)

    if args.write_baseline:
        Path(args.write_baseline).write_text(render_baseline(
            findings, note=f"generated over {' '.join(paths)}"))
        print(f"replint: wrote {len(findings)} skeleton entries to "
              f"{args.write_baseline} — fill in every justification "
              "before committing")
        return 0

    try:
        baseline = load_baseline(None if args.no_baseline else args.baseline)
    except (ValueError, json.JSONDecodeError) as e:
        print(f"replint: bad baseline: {e}", file=sys.stderr)
        return 2
    unsuppressed, suppressed, stale = baseline.split(findings)
    # Staleness is only provable for entries inside the analyzed scope: an
    # absent finding for a file we never parsed proves nothing.  This also
    # covers --changed-only, which sees a file subset by design.
    if args.changed_only:
        stale = []
    else:
        scope = [Path(p).resolve() for p in paths]
        ran = {r.rule_id for r in rules}
        stale = [e for e in stale
                 if e.rule in ran
                 and any(Path(e.path).resolve() == s
                         or s in Path(e.path).resolve().parents
                         for s in scope)]

    for f in unsuppressed:
        print(f.render())
    for e in stale:
        print(f"{e.path} {e.rule} STALE baseline entry "
              f"{e.fingerprint}: the finding it suppressed is gone — "
              "remove it (baseline may shrink, never grow)")

    if args.json:
        Path(args.json).write_text(json.dumps({
            "findings": [f.as_dict() for f in unsuppressed],
            "suppressed": [f.as_dict() for f in suppressed],
            "stale_baseline": [e.as_dict() for e in stale],
            "baseline_count": len(baseline),
            "files_analyzed": len(project.files),
        }, indent=2) + "\n")

    n, s = len(unsuppressed), len(suppressed)
    tail = f" ({s} suppressed by baseline)" if s else ""
    if n or stale:
        print(f"replint: {n} finding(s){tail}, "
              f"{len(stale)} stale baseline entr(y/ies) "
              f"over {len(project.files)} files")
        return 1
    print(f"replint: clean{tail} over {len(project.files)} files")
    return 0
