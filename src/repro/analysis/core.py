"""The replint rule engine: source model, findings, and the analysis driver.

Deliberately dependency-free (stdlib ``ast`` only) so the CI lint job can
run it next to ruff without installing the package's jax stack; nothing
here imports jax or the dataplane modules it analyses.

Two rule granularities (DESIGN.md §11):

  * per-file  — ``Rule.check_file(SourceFile)`` visits one parsed module;
  * project   — ``Rule.check_project(Project)`` sees the whole analyzed
    file set at once (the engine≡loop structural-parity rule RPL002 and
    the kernel-package hygiene rule RPL006 are cross-file by nature).

Findings carry a content fingerprint (rule | path | source-line text) so
the suppression baseline survives unrelated line-number drift but expires
the moment the suppressed line itself changes.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
from pathlib import Path
from typing import Iterable, Iterator


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One structured violation: ``path:line RPLnnn message``."""

    path: str      # posix path, relative to the analysis root
    line: int      # 1-based
    rule: str      # "RPL001".."RPL007"
    message: str
    snippet: str = ""   # stripped source line, fingerprint input

    @property
    def fingerprint(self) -> str:
        """Stable identity for baseline matching: immune to line-number
        drift, invalidated when the flagged line's text changes."""
        key = f"{self.rule}|{self.path}|{self.snippet}"
        return hashlib.sha256(key.encode()).hexdigest()[:16]

    def render(self) -> str:
        return f"{self.path}:{self.line} {self.rule} {self.message}"

    def as_dict(self) -> dict:
        return dict(path=self.path, line=self.line, rule=self.rule,
                    message=self.message, fingerprint=self.fingerprint)


@dataclasses.dataclass
class SourceFile:
    """One parsed module: path (relative, posix), text, AST, lines."""

    path: str
    text: str
    tree: ast.Module
    abspath: Path

    @property
    def lines(self) -> list[str]:
        return self.text.splitlines()

    def line_at(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    @property
    def parts(self) -> tuple[str, ...]:
        """Path segments — rules scope themselves by directory name
        (``nf``, ``switchsim``, ``kernels``, ``tests``, ...), which works
        identically for the real tree and for test fixture trees."""
        return tuple(Path(self.path).parts)

    def in_dir(self, *names: str) -> bool:
        return any(n in self.parts[:-1] for n in names)

    def finding(self, node: ast.AST | int, rule: str, message: str) -> Finding:
        line = node if isinstance(node, int) else node.lineno
        return Finding(path=self.path, line=line, rule=rule, message=message,
                       snippet=self.line_at(line))


@dataclasses.dataclass
class Project:
    """The analyzed file set plus the root they are relative to."""

    root: Path
    files: list[SourceFile]

    def find(self, *suffixes: str) -> SourceFile | None:
        """First analyzed file whose path ends with one of ``suffixes``
        (posix, e.g. ``"switchsim/engine.py"``)."""
        for sfx in suffixes:
            for f in self.files:
                if f.path == sfx or f.path.endswith("/" + sfx):
                    return f
        return None

    def load_sibling(self, anchor: SourceFile, relpath: str) -> SourceFile | None:
        """Load a file located relative to ``anchor``'s directory, whether
        or not it is part of the analyzed set (``--changed-only`` may hand
        a cross-file rule only one side of its invariant)."""
        target = (anchor.abspath.parent / relpath).resolve()
        for f in self.files:
            if f.abspath == target:
                return f
        return parse_file(target, self.root)


class Rule:
    """Base class: subclasses set ``rule_id``/``title`` and override one
    or both check methods.  Rules must never raise on weird-but-valid
    code — a rule that cannot decide stays silent (lint, not a verifier)."""

    rule_id: str = "RPL000"
    title: str = ""

    def check_file(self, f: SourceFile) -> Iterable[Finding]:
        return ()

    def check_project(self, project: Project) -> Iterable[Finding]:
        return ()


def parse_file(path: Path, root: Path) -> SourceFile | None:
    try:
        text = path.read_text(encoding="utf-8")
    except (OSError, UnicodeDecodeError):
        return None
    try:
        tree = ast.parse(text, filename=str(path))
    except SyntaxError:
        return None
    try:
        rel = path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        rel = path.as_posix()
    return SourceFile(path=rel, text=text, tree=tree, abspath=path.resolve())


def iter_py_files(paths: Iterable[Path]) -> Iterator[Path]:
    for p in paths:
        if p.is_dir():
            yield from sorted(q for q in p.rglob("*.py") if q.is_file())
        elif p.suffix == ".py" and p.is_file():
            yield p


def load_project(paths: Iterable[str | Path],
                 root: str | Path | None = None) -> Project:
    """Parse every .py under ``paths`` into a Project.  ``root`` anchors
    the relative paths findings report (default: cwd)."""
    rootp = Path(root) if root is not None else Path.cwd()
    files = []
    for p in iter_py_files(Path(p) for p in paths):
        sf = parse_file(p, rootp)
        if sf is not None:
            files.append(sf)
    return Project(root=rootp, files=files)


def analyze(project: Project, rules: Iterable[Rule]) -> list[Finding]:
    """Run every rule over the project; findings sorted by location."""
    findings: list[Finding] = []
    for rule in rules:
        for f in project.files:
            findings.extend(rule.check_file(f))
        findings.extend(rule.check_project(project))
    return sorted(set(findings))


# --------------------------------------------------------------------------
# Shared AST helpers used by several rules
# --------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> str:
    """``jax.lax.scan`` -> "jax.lax.scan"; "" when not a plain dotted path."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else ""
    return ""


def call_name(call: ast.Call) -> str:
    return dotted_name(call.func)


def str_const(node: ast.AST) -> str | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def walk_calls(node: ast.AST) -> Iterator[ast.Call]:
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            yield n


def func_defs(tree: ast.AST) -> Iterator[ast.FunctionDef]:
    for n in ast.walk(tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield n


def traced_functions(f: SourceFile) -> list[ast.FunctionDef]:
    """Functions in this module that run under a JAX trace.

    A function is considered traced when (transitively):
      * it is decorated with ``jax.jit`` / ``jax.vmap`` / ``pmap`` /
        ``shard_map`` or a ``partial(jax.jit, ...)`` thereof;
      * its name is passed to ``jax.jit(...)`` / ``jax.vmap(...)`` / a
        ``partial(jax.jit, ...)(...)`` call anywhere in the module (the
        ``split = partial(jax.jit, ...)(split_fn)`` idiom);
      * its name is the function operand of ``lax.scan`` / ``fori_loop`` /
        ``while_loop`` / ``cond`` / ``switch``;
      * it is a ``def`` nested inside a traced function (scan bodies).
    """
    wrappers = ("jit", "vmap", "pmap", "shard_map", "pallas_call",
                "checkpoint", "remat", "grad", "value_and_grad")
    lax_hofs = ("scan", "fori_loop", "while_loop", "cond", "switch",
                "associated_scan", "associative_scan", "map")

    def is_trace_wrapper(expr: ast.AST) -> bool:
        name = dotted_name(expr)
        if name.split(".")[-1] in wrappers and ("jax" in name or "pl" in name
                                                or name in wrappers):
            return True
        # partial(jax.jit, ...) / functools.partial(jax.jit, ...)
        if isinstance(expr, ast.Call) and \
                dotted_name(expr.func).split(".")[-1] == "partial":
            return any(is_trace_wrapper(a) for a in expr.args[:1])
        return False

    traced_names: set[str] = set()
    for call in walk_calls(f.tree):
        # jax.jit(run) / vmap(run) / partial(jax.jit, ...)(split_fn)
        if is_trace_wrapper(call.func):
            for a in call.args:
                if isinstance(a, ast.Name):
                    traced_names.add(a.id)
        # lax.scan(step, ...) and friends take the traced body first
        head = call_name(call).split(".")[-1]
        if head in lax_hofs and ("lax" in call_name(call)):
            for a in call.args[:1]:
                if isinstance(a, ast.Name):
                    traced_names.add(a.id)

    roots: list[ast.FunctionDef] = []
    for fn in func_defs(f.tree):
        if fn.name in traced_names:
            roots.append(fn)
            continue
        for dec in fn.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) and not \
                is_trace_wrapper(dec) else dec
            if is_trace_wrapper(target) or is_trace_wrapper(dec):
                roots.append(fn)
                break

    # close over nesting: any def inside a traced def is traced
    out: list[ast.FunctionDef] = []
    seen: set[int] = set()

    def add(fn: ast.FunctionDef) -> None:
        if id(fn) in seen:
            return
        seen.add(id(fn))
        out.append(fn)
        for sub in ast.walk(fn):
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and sub is not fn:
                add(sub)

    for fn in roots:
        add(fn)
    return out
