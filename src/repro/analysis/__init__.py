"""replint: project-specific static analysis for the PayloadPark repro.

Every headline number this repo reproduces rests on invariants that used
to be enforced only by reviewer convention: all per-packet math flows
through the backend ``dispatch`` (DESIGN.md §9), every counter and
telemetry field the engine carries is mirrored bit-exactly in the host
loop (the engine≡loop oracle, §3), table builds are process-deterministic
(the PR 4 salted-``hash()`` Maglev bug), and jitted hot paths neither
host-sync nor recompile per call.  ``repro.analysis`` makes those
invariants machine-checked on every PR: an AST-based rule engine with
structured ``file:line rule-id message`` findings, a committed suppression
baseline (shrink-only), and JSON output for CI.  See DESIGN.md §11.

CLI: ``python -m repro.analysis [paths...] [--json out.json]``.
"""
from repro.analysis.baseline import (Baseline, BaselineEntry,  # noqa: F401
                                     load_baseline)
from repro.analysis.core import (Finding, Project, Rule,  # noqa: F401
                                 SourceFile, analyze, load_project)
from repro.analysis.rules import ALL_RULES, rule_by_id  # noqa: F401
