"""RPL005 — host synchronization inside hot-path traced code.

The seed simulator paid a device->host round trip per chunk
(``int(jnp.sum(...))`` byte tallies); the scanned engine exists to remove
exactly that.  A ``.item()`` / ``float()`` / ``np.asarray()`` on a traced
value either crashes under jit (``ConcretizationTypeError``) or — when the
function sometimes runs eagerly — silently serializes the pipeline.

Scope: modules under ``switchsim/`` and ``backend/`` (plus ``kernels/``
and ``distributed/`` — ``shard_map`` bodies are traced code too, and a
host sync inside the fabric's per-shard program serializes every device,
DESIGN.md §12), and only INSIDE functions the tracer reaches (decorated
with ``jax.jit`` etc., wrapped via ``partial(jax.jit, ...)(fn)``, passed
to ``lax.scan``/``shard_map`` & friends, or nested in one).  Host-side
result finalization in the same modules (e.g. ``engine._sum_telemetry``)
stays legal.

Flags, within traced functions:

  * ``x.item()`` — synchronous device->host transfer;
  * ``np.*(...)`` — numpy on a traced value forces materialization;
  * ``float(...)`` / ``int(...)`` / ``bool(...)`` of a computed value
    (call/subscript/arithmetic operand; casts of config scalars are fine).
"""
from __future__ import annotations

import ast

from repro.analysis.core import (Rule, SourceFile, dotted_name,
                                 traced_functions, walk_calls)

HOT_DIRS = ("switchsim", "backend", "kernels", "distributed")


class HostSyncRule(Rule):
    rule_id = "RPL005"
    title = "host sync in hot path"

    def check_file(self, f: SourceFile):
        if not f.in_dir(*HOT_DIRS):
            return
        base = f.parts[-1]
        if base.startswith("test_") or base == "conftest.py":
            return
        for fn in traced_functions(f):
            for call in walk_calls(fn):
                name = dotted_name(call.func)
                if isinstance(call.func, ast.Attribute) and \
                        call.func.attr == "item" and not call.args:
                    yield f.finding(
                        call, self.rule_id,
                        ".item() inside a traced function is a synchronous "
                        "device->host transfer — keep the value on device "
                        "and reduce it after the scan")
                elif name.split(".")[0] in ("np", "numpy"):
                    yield f.finding(
                        call, self.rule_id,
                        f"{name}() inside a traced function materializes "
                        "the traced value on host — use jnp/lax")
                elif name in ("float", "int", "bool") and call.args and \
                        isinstance(call.args[0], (ast.Call, ast.Subscript,
                                                  ast.BinOp)):
                    yield f.finding(
                        call, self.rule_id,
                        f"{name}() of a computed value inside a traced "
                        "function host-syncs (the seed's per-chunk "
                        "int(jnp.sum(...)) defect class)")
