"""The replint rule set (RPL001–RPL007) — one module per invariant.

Each rule encodes a contract the repo's results depend on (DESIGN.md §11);
every rule cites the real defect class that motivated it.
"""
from __future__ import annotations

from repro.analysis.core import Rule
from repro.analysis.rules.determinism import NondeterminismRule
from repro.analysis.rules.dispatch import DispatchRule
from repro.analysis.rules.hostsync import HostSyncRule
from repro.analysis.rules.kernelhygiene import KernelHygieneRule
from repro.analysis.rules.oracletests import OracleTestRule
from repro.analysis.rules.parity import ParityRule
from repro.analysis.rules.recompile import RecompileRule

ALL_RULES: tuple[Rule, ...] = (
    DispatchRule(),
    ParityRule(),
    NondeterminismRule(),
    RecompileRule(),
    HostSyncRule(),
    KernelHygieneRule(),
    OracleTestRule(),
)


def rule_by_id(rule_id: str) -> Rule:
    for r in ALL_RULES:
        if r.rule_id == rule_id:
            return r
    raise KeyError(rule_id)
