"""RPL006 — kernel-package hygiene: signature parity + interpret path.

Every Pallas kernel package (``kernels/<name>/``) carries three files:
``ref.py`` (the jnp oracle the parity tests diff against), ``kernel.py``
(the ``pallas_call`` body), and ``ops.py`` (the jitted public wrapper).
Two structural invariants keep the "pallas" benchmark column honest:

  * the ops wrapper's signature must match the oracle's (modulo the
    ``interpret`` flag and layout-only ``_u8`` suffixes), so the registry
    can swap implementations without per-call-site shims;
  * the interpret path must be real end-to-end: the wrapper takes an
    ``interpret`` kwarg AND forwards it to the kernel call, and the
    kernel function exposes it.  A wrapper that takes ``interpret`` but
    drops it silently runs ONE mode whatever the caller asked — under
    ``backend="pallas"`` the benchmark then measures interpret mode (or
    vice versa), which is precisely the silent-substrate-fallback failure
    NFSlicer warns about.
"""
from __future__ import annotations

import ast

from repro.analysis.core import (Project, Rule, SourceFile, dotted_name,
                                 walk_calls)


def _norm(name: str) -> str:
    for sfx in ("_kernel_op", "_ref", "_u8"):
        if name.endswith(sfx):
            name = name[: -len(sfx)]
    return name


def _params(fn: ast.FunctionDef) -> list[str]:
    names = [a.arg for a in fn.args.posonlyargs + fn.args.args
             + fn.args.kwonlyargs]
    return [_norm(n) for n in names if n != "interpret"]


def _defs(f: SourceFile) -> dict[str, ast.FunctionDef]:
    return {n.name: n for n in ast.walk(f.tree)
            if isinstance(n, ast.FunctionDef)}


def _kernel_imports(ops: SourceFile) -> set[str]:
    """Names imported from the sibling ``kernel`` module that look like
    kernel entry points."""
    out: set[str] = set()
    for node in ast.walk(ops.tree):
        if isinstance(node, ast.ImportFrom) and node.module and \
                node.module.split(".")[-1] == "kernel":
            for alias in node.names:
                if alias.name.endswith("_kernel"):
                    out.add(alias.asname or alias.name)
    return out


def _resolve_ref_params(project: Project, ref: SourceFile,
                        wrapper_name: str) -> list[str] | None:
    """Parameter list of the oracle matching ``wrapper_name``: a local
    ``def`` in ref.py, or a re-export resolved into backend/ref.py."""
    want = _norm(wrapper_name)
    for name, fn in _defs(ref).items():
        if _norm(name) == want:
            return _params(fn)
    for node in ast.walk(ref.tree):
        if not (isinstance(node, ast.ImportFrom) and node.module):
            continue
        for alias in node.names:
            if _norm(alias.asname or alias.name) != want:
                continue
            src = project.find("backend/ref.py") or \
                project.load_sibling(ref, "../../backend/ref.py")
            if src is not None:
                fn = _defs(src).get(alias.name)
                if fn is not None:
                    return _params(fn)
    return None


class KernelHygieneRule(Rule):
    rule_id = "RPL006"
    title = "kernel package hygiene"

    def check_project(self, project: Project):
        for ops in project.files:
            if ops.parts[-1] != "ops.py":
                continue
            kernel = project.load_sibling(ops, "kernel.py")
            if kernel is None:
                continue    # not a kernel package
            yield from self._check_package(project, ops, kernel)

    def _check_package(self, project: Project, ops: SourceFile,
                       kernel: SourceFile):
        kernel_names = _kernel_imports(ops)
        if not kernel_names:
            return
        ref = project.load_sibling(ops, "ref.py")
        if ref is None:
            yield ops.finding(1, self.rule_id,
                              "kernel package has no ref.py oracle")
        kdefs = _defs(kernel)

        for kname in sorted(kernel_names):
            kfn = kdefs.get(kname)
            if kfn is not None and "interpret" not in [
                    a.arg for a in kfn.args.posonlyargs + kfn.args.args
                    + kfn.args.kwonlyargs]:
                yield kernel.finding(
                    kfn, self.rule_id,
                    f"kernel '{kname}' exposes no interpret parameter — "
                    "every kernel must run under interpret mode for "
                    "CPU-only CI validation")

        for fn in _defs(ops).values():
            calls = [c for c in walk_calls(fn)
                     if dotted_name(c.func) in kernel_names]
            if not calls:
                continue
            has_interpret = "interpret" in [
                a.arg for a in fn.args.posonlyargs + fn.args.args
                + fn.args.kwonlyargs]
            if not has_interpret:
                yield ops.finding(
                    fn, self.rule_id,
                    f"ops wrapper '{fn.name}' takes no interpret kwarg — "
                    "callers cannot select compiled vs interpret mode")
            for call in calls:
                if not any(kw.arg == "interpret" for kw in call.keywords):
                    yield ops.finding(
                        call, self.rule_id,
                        f"'{fn.name}' does not forward interpret to "
                        f"'{dotted_name(call.func)}' — the kernel runs one "
                        "hardcoded mode whatever the caller asked for")
            if ref is None:
                continue
            ref_params = _resolve_ref_params(project, ref, fn.name)
            if ref_params is not None and _params(fn) != ref_params:
                yield ops.finding(
                    fn, self.rule_id,
                    f"ops wrapper '{fn.name}' signature {_params(fn)} does "
                    f"not match its ref oracle {ref_params} — the registry "
                    "swaps implementations by signature")
