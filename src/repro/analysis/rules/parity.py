"""RPL002 — engine≡loop structural parity.

The scanned engine (``engine.py``) and the host-side reference loop
(``simulate.py``) are the two halves of the repo's bit-exactness oracle:
every counter the engine bumps and every ``LinkTelemetry`` field it tallies
must be mirrored by the loop, or the oracle silently stops covering that
quantity.  The runtime tests only catch a divergence in the VALUES; this
rule catches the structural half — adding a counter or telemetry field to
one side without the other now fails lint, not review.

For every analyzed ``engine.py`` with a sibling ``simulate.py``:

  * the set of counter names bumped (``C.bump(..., "name", ...)``) in
    ``engine.py`` must equal the set bumped inside ``simulate.py``'s loop
    functions (any ``def`` whose name contains ``loop``);
  * the telemetry keys the engine surfaces in its per-step ``ys`` and the
    keys the loop accumulates (``tel["name"] += ...``) must each cover the
    ``LinkTelemetry`` field set (sibling ``telemetry.py``), and neither
    side may write a ``*_pkts``/``*_bytes`` key the struct does not carry.
"""
from __future__ import annotations

import ast

from repro.analysis.core import (Project, Rule, SourceFile, dotted_name,
                                 str_const, walk_calls)


def _bumped_counters(tree: ast.AST) -> dict[str, int]:
    """name -> first line for every ``...bump(..., "name", ...)`` call."""
    out: dict[str, int] = {}
    for call in walk_calls(tree):
        if dotted_name(call.func).split(".")[-1] != "bump":
            continue
        for arg in call.args:
            s = str_const(arg)
            if s is not None:
                out.setdefault(s, call.lineno)
                break
    return out


def _loop_functions(f: SourceFile) -> list[ast.FunctionDef]:
    return [n for n in ast.walk(f.tree)
            if isinstance(n, ast.FunctionDef) and "loop" in n.name]


def _engine_ys_keys(f: SourceFile) -> dict[str, int]:
    """Telemetry keys the engine's scan surfaces: keywords of a ``dict(...)``
    assigned to ``ys`` plus ``ys["key"] = ...`` stores."""
    out: dict[str, int] = {}
    for node in ast.walk(f.tree):
        if isinstance(node, ast.Assign):
            targets = [t for t in node.targets if isinstance(t, ast.Name)]
            if any(t.id == "ys" for t in targets) and \
                    isinstance(node.value, ast.Call) and \
                    dotted_name(node.value.func) == "dict":
                for kw in node.value.keywords:
                    if kw.arg:
                        out.setdefault(kw.arg, kw.value.lineno)
            for t in node.targets:
                if isinstance(t, ast.Subscript) and \
                        isinstance(t.value, ast.Name) and t.value.id == "ys":
                    key = str_const(t.slice)
                    if key:
                        out.setdefault(key, node.lineno)
    return out


def _loop_tel_keys(fns: list[ast.FunctionDef]) -> dict[str, int]:
    """Keys of ``tel["name"] += ...`` accumulations across loop functions."""
    out: dict[str, int] = {}
    for fn in fns:
        for node in ast.walk(fn):
            target = None
            if isinstance(node, ast.AugAssign):
                target = node.target
            elif isinstance(node, ast.Assign) and len(node.targets) == 1:
                target = node.targets[0]
            if isinstance(target, ast.Subscript) and \
                    isinstance(target.value, ast.Name) and \
                    target.value.id == "tel":
                key = str_const(target.slice)
                if key:
                    out.setdefault(key, node.lineno)
    return out


def _tel_fields(f: SourceFile | None) -> set[str]:
    """Field names of the LinkTelemetry dataclass in telemetry.py."""
    if f is None:
        return set()
    for node in ast.walk(f.tree):
        if isinstance(node, ast.ClassDef) and node.name == "LinkTelemetry":
            return {s.target.id for s in node.body
                    if isinstance(s, ast.AnnAssign)
                    and isinstance(s.target, ast.Name)}
    return set()


def _looks_telemetry(key: str) -> bool:
    return key.endswith("_pkts") or key.endswith("_bytes")


class ParityRule(Rule):
    rule_id = "RPL002"
    title = "engine/loop structural parity"

    def check_project(self, project: Project):
        for eng in project.files:
            if eng.parts[-1] != "engine.py":
                continue
            sim = project.load_sibling(eng, "simulate.py")
            if sim is None:
                continue
            loops = _loop_functions(sim)
            if not loops:
                continue

            eng_ctr = _bumped_counters(eng.tree)
            loop_ctr: dict[str, int] = {}
            for fn in loops:
                for k, v in _bumped_counters(fn).items():
                    loop_ctr.setdefault(k, v)
            for name in sorted(set(eng_ctr) - set(loop_ctr)):
                yield eng.finding(
                    eng_ctr[name], self.rule_id,
                    f"engine bumps counter '{name}' but no simulate.py loop "
                    "function mirrors it — the engine≡loop oracle no "
                    "longer covers this counter")
            for name in sorted(set(loop_ctr) - set(eng_ctr)):
                yield sim.finding(
                    loop_ctr[name], self.rule_id,
                    f"loop bumps counter '{name}' but engine.py does not — "
                    "the engine≡loop oracle no longer covers this "
                    "counter")

            tel_fields = _tel_fields(project.load_sibling(eng, "telemetry.py"))
            if not tel_fields:
                continue
            ys = _engine_ys_keys(eng)
            tel = _loop_tel_keys(loops)
            for name in sorted(tel_fields - set(ys)):
                yield eng.finding(
                    1, self.rule_id,
                    f"LinkTelemetry field '{name}' is never surfaced in the "
                    "engine's ys")
            for name in sorted(tel_fields - set(tel)):
                yield sim.finding(
                    1, self.rule_id,
                    f"LinkTelemetry field '{name}' is never accumulated by "
                    "any simulate.py loop function")
            for name in sorted(k for k in ys
                               if _looks_telemetry(k) and k not in tel_fields):
                yield eng.finding(
                    ys[name], self.rule_id,
                    f"engine surfaces telemetry-shaped ys key '{name}' that "
                    "is not a LinkTelemetry field — add the field or rename")
            for name in sorted(k for k in tel
                               if _looks_telemetry(k) and k not in tel_fields):
                yield sim.finding(
                    tel[name], self.rule_id,
                    f"loop accumulates telemetry key '{name}' that is not a "
                    "LinkTelemetry field — add the field or rename")
