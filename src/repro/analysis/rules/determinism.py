"""RPL003 — process-nondeterminism ban (the salted-``hash()`` class).

PR 4 shipped a Maglev table build keyed on builtin ``hash(name)``:
``PYTHONHASHSEED`` salts string hashes per process, so every fresh
interpreter built a DIFFERENT permutation table — results were
self-consistent within a run and unreproducible across runs, the worst
kind of wrong.  The fix (``nf/maglev.py::_mix64``) replaced it with an
explicit splitmix64.  This rule bans the whole defect class:

  * builtin ``hash(...)`` — salted for str/bytes, never reproducible;
  * ``time.time()`` / ``time.time_ns()`` — wall clock feeding logic
    (benchmark timing is exempted via the suppression baseline, where the
    exemption is visible and counted);
  * iterating a ``set`` (literal, ``set(...)`` call, or comprehension) —
    iteration order depends on the salted hashes, so any table or list
    built from it is process-dependent; iterate ``sorted(...)`` instead.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Rule, SourceFile, dotted_name, walk_calls


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and dotted_name(node.func) in ("set",
                                                                 "frozenset"):
        return True
    return False


class NondeterminismRule(Rule):
    rule_id = "RPL003"
    title = "process-nondeterministic construct"

    def check_file(self, f: SourceFile):
        for call in walk_calls(f.tree):
            name = dotted_name(call.func)
            if name == "hash":
                yield f.finding(
                    call, self.rule_id,
                    "builtin hash() is PYTHONHASHSEED-salted per process — "
                    "use an explicit mix (e.g. splitmix64, cf. "
                    "nf/maglev.py:_mix64) so table builds reproduce")
            elif name in ("time.time", "time.time_ns"):
                yield f.finding(
                    call, self.rule_id,
                    f"{name}() feeds wall-clock nondeterminism into the "
                    "program — derive logic from seeds/config; timing-only "
                    "uses belong in the suppression baseline")
        for node in ast.walk(f.tree):
            iters = []
            if isinstance(node, (ast.For, ast.AsyncFor)):
                iters.append(node.iter)
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                iters.extend(g.iter for g in node.generators)
            for it in iters:
                if _is_set_expr(it):
                    yield f.finding(
                        it, self.rule_id,
                        "iterating a set: order is salted-hash-dependent, "
                        "so anything built from it varies per process — "
                        "iterate sorted(...) instead")
