"""RPL001 — hot-path primitive math must flow through the backend registry.

The PR 5 contract (DESIGN.md §9): the five per-packet primitives (and their
low-level helpers) are implemented once in ``backend/ref.py`` and
``kernels/*``, and every dataplane call site reaches them through
``registry.dispatch(name, backend)``.  A direct import or call bypasses the
backend axis — the benchmark's "pallas" column silently measures the ref
path for that stage, exactly the shallow-NF failure NFSlicer documents.

Flags, in dataplane modules (everything except ``backend/``, ``kernels/``,
``analysis/`` and test files):

  * ``from repro.backend.ref import crc16_tag`` (importing a primitive
    function; ALL_CAPS constants like ``CRC_POLY`` stay importable);
  * calls whose terminal name is a primitive (``crc16_tag(...)``,
    ``ref.acl_match(...)``) when the module does not define it locally.

A call carrying a ``backend=`` keyword is exempt: that is the signature of
the sanctioned dispatch-routed wrappers (``core/header.crc16_tag`` routes
through ``registry.dispatch`` and threads the caller's backend), not of
the single-implementation functions this rule guards.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Rule, SourceFile, dotted_name, walk_calls

# The registry's primitive surface plus the helpers backend/ref.py builds
# them from — the names whose implementations must stay single-sourced.
PRIMITIVE_FUNCS = frozenset({
    "crc16_tag", "acl_match", "maglev_select",
    "payload_store", "payload_fetch",
    "crc16_bytes", "tag_bytes", "maglev_hash5",
})

# Modules allowed to touch primitives directly: the implementations
# themselves, their kernels, the tests that assert cross-impl parity, and
# this analyzer.
EXEMPT_DIRS = ("backend", "kernels", "analysis", "tests")


def _scoped(f: SourceFile) -> bool:
    if f.in_dir(*EXEMPT_DIRS):
        return False
    base = f.parts[-1]
    return not (base.startswith("test_") or base == "conftest.py")


class DispatchRule(Rule):
    rule_id = "RPL001"
    title = "primitive math outside the backend dispatch"

    def check_file(self, f: SourceFile):
        if not _scoped(f):
            return
        local_defs = {n.name for n in ast.walk(f.tree)
                      if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        for node in ast.walk(f.tree):
            if isinstance(node, ast.ImportFrom) and node.module and \
                    ("backend" in node.module.split(".")
                     or "kernels" in node.module.split(".")):
                for alias in node.names:
                    if alias.name in PRIMITIVE_FUNCS:
                        yield f.finding(
                            node, self.rule_id,
                            f"imports primitive '{alias.name}' from "
                            f"'{node.module}' — dataplane call sites must "
                            "use registry.dispatch (constants are fine)")
        for call in walk_calls(f.tree):
            name = dotted_name(call.func)
            leaf = name.split(".")[-1] if name else ""
            if leaf in PRIMITIVE_FUNCS and leaf not in local_defs and \
                    not any(kw.arg == "backend" for kw in call.keywords):
                yield f.finding(
                    call, self.rule_id,
                    f"direct call to primitive '{leaf}' — route through "
                    "registry.dispatch so the backend axis covers this "
                    "stage")
