"""RPL004 — recompile hazards on the jit boundary.

The engine's whole performance story is ONE compiled program per
configuration: static jit args (and the ``lru_cache`` keys built from
them) must be hashable and immutable, or each call either crashes
(``unhashable type``) or — worse — recompiles silently.  Config-like
dataclasses are this repo's static-arg currency (``ParkConfig``,
``BackendConfig``, ``ScenarioSpec``, ``FaultSpec`` are all frozen).

Flags:

  * a ``@dataclasses.dataclass`` class whose name ends in ``Config`` or
    ``Spec`` that is not declared ``frozen=True`` — non-frozen means
    unhashable (no ``eq``-consistent ``__hash__``) and mutable under the
    jit cache's feet;
  * f-strings interpolating ``.shape`` inside traced functions — the
    format runs at trace time, so the string bakes in one shape and is a
    tell that shape-dependent python logic is hiding under the jit.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Rule, SourceFile, dotted_name, traced_functions

STATIC_SUFFIXES = ("Config", "Spec")


def _dataclass_decorator(cls: ast.ClassDef) -> ast.AST | None:
    for dec in cls.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        if dotted_name(target).split(".")[-1] == "dataclass":
            return dec
    return None


def _frozen_true(dec: ast.AST) -> bool:
    if not isinstance(dec, ast.Call):
        return False    # bare @dataclass
    for kw in dec.keywords:
        if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
            return bool(kw.value.value)
    return False


class RecompileRule(Rule):
    rule_id = "RPL004"
    title = "jit recompile hazard"

    def check_file(self, f: SourceFile):
        base = f.parts[-1]
        if base.startswith("test_") or base == "conftest.py" \
                or f.in_dir("tests"):
            return
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            if not node.name.endswith(STATIC_SUFFIXES):
                continue
            dec = _dataclass_decorator(node)
            if dec is not None and not _frozen_true(dec):
                yield f.finding(
                    node, self.rule_id,
                    f"dataclass '{node.name}' is not frozen=True — "
                    "*Config/*Spec classes are jit static args / cache "
                    "keys and must be hashable and immutable")
        for fn in traced_functions(f):
            for node in ast.walk(fn):
                if not isinstance(node, ast.JoinedStr):
                    continue
                for part in node.values:
                    if isinstance(part, ast.FormattedValue) and any(
                            isinstance(n, ast.Attribute) and n.attr == "shape"
                            for n in ast.walk(part.value)):
                        yield f.finding(
                            node, self.rule_id,
                            "f-string of a .shape inside a traced function "
                            "formats at trace time and bakes in one shape — "
                            "hoist it out of the jitted region")
                        break
