"""RPL007 — oracle-test discipline: bit-exact claims get exact asserts.

The engine≡loop and cross-backend contracts are BIT-exact (integer packet
bytes, counters, telemetry) — that exactness is what lets the pallas
column share the ref run's committed benchmark baselines.  A test that
asserts such a contract with ``allclose``/``rtol`` quietly weakens it to
"approximately reproduces", and a real divergence (an off-by-one counter,
a truncated byte) can hide inside the tolerance forever.

Flags, inside test functions whose name/class marks them as exactness
oracles (``oracle``, ``bitexact``, ``parity``, ``engine``+``loop``,
``cross_backend``, ``backends_match``, or any test calling a
``*oracle*`` helper): calls to ``allclose``/``isclose``/``approx`` and
``rtol=``/``atol=`` keywords.  Use ``array_equal`` / ``==`` instead.
Genuinely approximate kernels (float attention) belong in the suppression
baseline with the numerical justification spelled out.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.core import Rule, SourceFile, dotted_name, walk_calls

EXACTNESS = re.compile(
    r"oracle|bit_?exact|parity|cross_?backend|backends?_match"
    r"|engine.*loop|loop.*engine|matches_loop|matches_engine")

APPROX_CALLS = ("allclose", "isclose", "approx")


def _is_test_file(f: SourceFile) -> bool:
    return f.parts[-1].startswith("test_") or f.in_dir("tests")


def _exactness_scoped(fn: ast.FunctionDef, classname: str) -> bool:
    if EXACTNESS.search(f"{classname} {fn.name}".lower()):
        return True
    return any("oracle" in dotted_name(c.func).lower()
               for c in walk_calls(fn))


class OracleTestRule(Rule):
    rule_id = "RPL007"
    title = "approximate assert in a bit-exactness test"

    def check_file(self, f: SourceFile):
        if not _is_test_file(f):
            return
        for cls, fn in _test_functions(f.tree):
            if not fn.name.startswith("test_"):
                continue
            if not _exactness_scoped(fn, cls):
                continue
            for call in walk_calls(fn):
                leaf = dotted_name(call.func).split(".")[-1]
                if leaf in APPROX_CALLS:
                    yield f.finding(
                        call, self.rule_id,
                        f"{leaf}() in a bit-exactness test weakens the "
                        "oracle to 'approximately equal' — assert exact "
                        "equality (array_equal / ==)")
                for kw in call.keywords:
                    if kw.arg in ("rtol", "atol") and leaf not in APPROX_CALLS:
                        yield f.finding(
                            call, self.rule_id,
                            f"{kw.arg}= tolerance in a bit-exactness test — "
                            "assert exact equality (array_equal / ==)")
    # rtol/atol on an allclose call would double-report; the keyword branch
    # only covers tolerance kwargs smuggled into other comparison helpers.


def _test_functions(tree: ast.Module):
    for node in tree.body:
        if isinstance(node, ast.FunctionDef):
            yield "", node
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, ast.FunctionDef):
                    yield node.name, sub
