"""Shared CLI surface for the bench family (ISSUE 9 / DESIGN.md §13).

Every bench — pipeline, hostmodel, chain, adversarial, streaming — used to
hand-roll its own ``argparse`` setup, and the shared flags drifted: some had
``--no-verify``, some could not skip their oracle at all, only one took
``--backend``.  ``base_parser()`` is the single parent parser defining the
five flags every bench accepts with identical spellings and defaults:

  ``--tiny``       CI-smoke geometry (each bench documents its tiny shape);
  ``--json PATH``  write the schema-v2 BENCH artifact (artifacts.py);
  ``--no-verify``  skip the bench's oracle cross-check;
  ``--oracle``     force the oracle cross-check on where a bench defaults
                   it off (mutually exclusive with ``--no-verify``);
  ``--backend``    dataplane backend(s) (repro.backend).  Benches that run
                   one backend reject a multi-value sweep via
                   ``single_backend``; bench_pipeline sweeps them.

Bench-specific flags stay in each bench, added on top of the parent.
"""
from __future__ import annotations

import argparse

BACKEND_CHOICES = ("ref", "pallas", "pallas_interpret", "auto")


def base_parser() -> argparse.ArgumentParser:
    """The parent parser (``add_help=False``) carrying the shared flags."""
    p = argparse.ArgumentParser(add_help=False)
    p.add_argument("--tiny", action="store_true",
                   help="CI smoke: the bench's documented tiny geometry")
    p.add_argument("--json", metavar="PATH",
                   help="also write the BENCH json artifact here "
                        "(benchmarks/artifacts.py schema v2)")
    p.add_argument("--no-verify", action="store_true",
                   help="skip this bench's oracle cross-check")
    p.add_argument("--oracle", action="store_true",
                   help="force the oracle cross-check on where this bench "
                        "defaults it off")
    p.add_argument("--backend", nargs="+", default=None,
                   choices=list(BACKEND_CHOICES),
                   help="dataplane backend(s) (repro.backend); benches "
                        "that run one backend reject a multi-value sweep")
    return p


def make_parser(description: str) -> argparse.ArgumentParser:
    """A bench's parser: the shared parent plus room for its own flags."""
    return argparse.ArgumentParser(description=description,
                                   parents=[base_parser()])


def check_flags(ap: argparse.ArgumentParser, args) -> None:
    """Shared post-parse validation; call right after ``parse_args``."""
    if args.no_verify and args.oracle:
        ap.error("--no-verify and --oracle are mutually exclusive")


def single_backend(ap: argparse.ArgumentParser, args) -> str | None:
    """The one backend for a non-sweeping bench; None = bench default."""
    if args.backend is None:
        return None
    if len(args.backend) > 1:
        ap.error("this bench runs a single --backend "
                 "(bench_pipeline sweeps them)")
    return args.backend[0]


def print_rows(rows) -> None:
    """The common ``name,value,derived`` CSV emission."""
    print("name,value,derived")
    for row in rows:
        name, value, derived = row[0], row[1], row[2]
        print(f"{name},{value},{str(derived).replace(',', ';')}")
