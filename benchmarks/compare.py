"""CI benchmark-regression gate: diff BENCH_*.json against baselines.

The per-commit benchmark trajectory used to be write-only — CI uploaded
the artifacts but nothing failed when a number drifted.  This gate closes
that hole: every candidate artifact is compared row-by-row against the
committed reference under ``benchmarks/baselines/`` with per-metric
relative tolerances, and any violation (or schema mismatch, or a baseline
row missing from the candidate) exits non-zero with a per-row diff.

Tolerance rules (first regex match on the row name wins):

  * timing metrics (pps, wall seconds, speedups) are NOT gated — they are
    runner-hardware noise, reported for the trajectory only — EXCEPT the
    fabric scaling rows (``fabric/*/pps``), which carry a deliberately
    wide relative band: correctness rows in ``BENCH_fabric.json``
    (``shard_invariance_identical``) gate exactly, timing rows gate
    loosely enough for runner noise but tight enough to catch a sharding
    path that stops compiling to one program (DESIGN.md §12);
  * exactness metrics (oracle ``identical`` flags) must match bit-for-bit;
  * ratio metrics (gains/savings/reductions/deltas) get a relative band
    plus a small absolute floor (ratios near zero would otherwise gate on
    relative noise);
  * everything else (byte totals, counters) gets a tight relative band.

The simulation is deterministic (fixed PRNG keys, deterministic Maglev
table), so in practice equal code produces equal artifacts; the bands
absorb cross-version JAX drift without letting a real regression through.

Artifacts carrying a ``degradation`` block (the adversarial families,
DESIGN.md §10) are additionally gated on their graceful-degradation
verdicts: any false gate fails the comparison, and every gate present in
the committed baseline must still exist in the candidate.

Baselines are matched per backend: a candidate is first matched to a
baseline by basename (so a committed ``BENCH_pipeline_pallas_interpret``
baseline wins if one exists); failing that, a candidate that records a
``backend`` provenance field falls back to its bench's backend-agnostic
baseline (``BENCH_<bench>.json``).  The backends are bit-exact by
construction (tests/test_backend.py), so the ONE committed ref baseline
gates every backend's numeric rows — a Pallas run that drifts from the ref
numbers fails CI exactly like a ref regression (timing rows stay exempt).
When both artifacts record a ``backend``, it must match.

    python benchmarks/compare.py BENCH_pipeline.json BENCH_chain.json
    python benchmarks/compare.py --baselines benchmarks/baselines BENCH_*.json

Exit codes: 0 ok, 1 metric regression, 2 schema/IO mismatch.
"""
from __future__ import annotations

import argparse
import os
import re
import sys

try:
    from benchmarks.artifacts import (BenchArtifactError, load_bench_json,
                                      row_map)
except ImportError:  # run as a script: benchmarks/ itself is on sys.path
    from artifacts import BenchArtifactError, load_bench_json, row_map

DEFAULT_BASELINES = os.path.join(os.path.dirname(__file__), "baselines")

# (name regex, rtol, atol); rtol None = not gated.  First match wins.
# Timing patterns are anchored to full path segments — an unanchored
# "wall" would silently exempt any future "firewall" metric from the gate.
# Fabric scaling pps rows come FIRST: unlike the other timing rows they
# are tolerance-banded (the ROADMAP follow-through on gating timing) —
# the band is deliberately wide (9x relative) so CI-runner noise on tiny
# sharded smokes passes while an order-of-magnitude dispatch collapse
# (e.g. shard_map silently falling back to per-pipe dispatch) fails.
TOLERANCES: list[tuple[str, float | None, float]] = [
    (r"^fabric/.*/pps$", 9.0, 0.0),
    # streaming steady-state pps carries the same wide band as the fabric
    # rows: runner noise passes, a dispatch/donation collapse (an order of
    # magnitude) fails.  RSS is absolute-machine-dependent and not gated —
    # the gated memory verdict is the constant_memory_ok row (catch-all
    # band: any 0 against a baseline 1 fails).
    (r"^streaming/.*/pps$", 9.0, 0.0),
    (r"(/peak_rss_mb$|/rss_growth_mb$)", None, 0.0),
    (r"(/pps$|/wall_s$|/speedup$|_s$)", None, 0.0),
    (r"identical", 0.0, 0.0),
    (r"(gain|saving|reduction|delta|uplift|rate)", 0.08, 0.02),
    (r"", 0.05, 0.0),
]


def tolerance_for(name: str) -> tuple[float | None, float]:
    for pat, rtol, atol in TOLERANCES:
        if re.search(pat, name):
            return rtol, atol
    raise AssertionError("unreachable: catch-all tolerance")


def _is_number(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def compare_rows(baseline: dict, candidate: dict) -> list[str]:
    """Per-row diffs between two loaded artifacts; empty list = pass."""
    problems = []
    base_rows, cand_rows = row_map(baseline), row_map(candidate)
    for name, brow in base_rows.items():
        if name not in cand_rows:
            problems.append(f"MISSING  {name}: in baseline, not in candidate")
            continue
        bval, cval = brow["value"], cand_rows[name]["value"]
        rtol, atol = tolerance_for(name)
        if rtol is None:
            continue
        if _is_number(bval) and _is_number(cval):
            lim = max(rtol * abs(bval), atol)
            if abs(cval - bval) > lim:
                problems.append(
                    f"DRIFT    {name}: baseline={bval} candidate={cval} "
                    f"(|delta|={abs(cval - bval):.6g} > tol={lim:.6g})")
        elif bval != cval:
            problems.append(
                f"MISMATCH {name}: baseline={bval!r} candidate={cval!r}")
    for name in sorted(set(cand_rows) - set(base_rows)):
        problems.append(
            f"NEW      {name}: not in baseline (regenerate baselines "
            f"to start gating it)")
    return problems


def _gate_key(scenario: str, gate: dict) -> str:
    return f"{scenario}:{gate['metric']}"


def compare_degradation(baseline: dict, candidate: dict) -> list[str]:
    """Graceful-degradation gate (DESIGN.md §10): any false ``ok`` flag in
    the candidate's ``degradation`` block fails the comparison like a
    tolerance breach, and every gate present in the committed baseline must
    still exist in the candidate (a family cannot silently stop gating an
    invariant)."""
    problems = []
    cand = candidate.get("degradation")
    base = baseline.get("degradation")
    if cand is not None:
        for name, sc in cand["scenarios"].items():
            for g in sc["gates"]:
                if not g["ok"]:
                    problems.append(
                        f"INVARIANT {name}: {g['metric']} = {g['value']} "
                        f"violates {g['metric']} {g['op']} {g['bound']}")
    if base is not None:
        if cand is None:
            problems.append(
                "MISSING  degradation block: in baseline, not in candidate")
            return problems
        have = {_gate_key(n, g) for n, sc in cand["scenarios"].items()
                for g in sc["gates"]}
        for name, sc in base["scenarios"].items():
            for g in sc["gates"]:
                if _gate_key(name, g) not in have:
                    problems.append(
                        f"MISSING  degradation gate {name}:{g['metric']}: "
                        f"in baseline, not in candidate")
    return problems


def compare_files(baseline_path: str, candidate_path: str,
                  candidate_payload: dict | None = None) -> list[str]:
    """``candidate_payload`` lets callers that already loaded the
    candidate (main's baseline resolution) skip a second parse."""
    baseline = load_bench_json(baseline_path)
    candidate = (candidate_payload if candidate_payload is not None
                 else load_bench_json(candidate_path))
    if baseline["bench"] != candidate["bench"]:
        return [f"MISMATCH bench name: baseline={baseline['bench']!r} "
                f"candidate={candidate['bench']!r}"]
    # Backend provenance must agree when both sides were produced for the
    # same artifact name; a basename MISS fell back to the backend-agnostic
    # baseline on purpose (cross-backend numeric gating), so differing
    # backends are exactly the point there.
    same_name = (os.path.basename(baseline_path)
                 == os.path.basename(candidate_path))
    if (same_name and "backend" in baseline and "backend" in candidate
            and baseline["backend"] != candidate["backend"]):
        return [f"MISMATCH backend: baseline={baseline['backend']!r} "
                f"candidate={candidate['backend']!r}"]
    return (compare_rows(baseline, candidate)
            + compare_degradation(baseline, candidate))


def resolve_baseline(baselines_dir: str, candidate_path: str,
                     candidate_payload: dict | None = None) -> str:
    """Per-backend baseline resolution (see module docstring): exact
    basename first, then — for candidates recording a ``backend`` — the
    bench's backend-agnostic ``BENCH_<bench>.json``."""
    base = os.path.join(baselines_dir, os.path.basename(candidate_path))
    if os.path.exists(base):
        return base
    payload = (candidate_payload if candidate_payload is not None
               else load_bench_json(candidate_path))
    if payload.get("backend"):
        alt = os.path.join(baselines_dir, f"BENCH_{payload['bench']}.json")
        if os.path.exists(alt):
            return alt
    return base  # missing: load_bench_json reports it with the right name


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("candidates", nargs="+", metavar="BENCH_JSON",
                    help="candidate artifacts written by this commit's "
                         "bench runs")
    ap.add_argument("--baselines", default=DEFAULT_BASELINES,
                    help="directory of committed reference artifacts "
                         "(matched by basename)")
    args = ap.parse_args(argv)

    failed = False
    for cand in args.candidates:
        try:
            payload = load_bench_json(cand)
            base = resolve_baseline(args.baselines, cand, payload)
            problems = compare_files(base, cand, payload)
        except BenchArtifactError as e:
            print(f"compare: {e}", file=sys.stderr)
            return 2
        gating = [p for p in problems if not p.startswith("NEW")]
        label = "FAIL" if gating else "ok"
        print(f"[{label}] {cand} vs {base}: "
              f"{len(gating)} regression(s)")
        for p in problems:
            print(f"  {p}")
        failed |= bool(gating)
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
