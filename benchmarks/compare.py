"""CI benchmark-regression gate: diff BENCH_*.json against baselines.

The per-commit benchmark trajectory used to be write-only — CI uploaded
the artifacts but nothing failed when a number drifted.  This gate closes
that hole: every candidate artifact is compared row-by-row against the
committed reference under ``benchmarks/baselines/`` with per-metric
relative tolerances, and any violation (or schema mismatch, or a baseline
row missing from the candidate) exits non-zero with a per-row diff.

Tolerance rules (first regex match on the row name wins):

  * timing metrics (pps, wall seconds, speedups) are NOT gated — they are
    runner-hardware noise, reported for the trajectory only;
  * exactness metrics (oracle ``identical`` flags) must match bit-for-bit;
  * ratio metrics (gains/savings/reductions/deltas) get a relative band
    plus a small absolute floor (ratios near zero would otherwise gate on
    relative noise);
  * everything else (byte totals, counters) gets a tight relative band.

The simulation is deterministic (fixed PRNG keys, deterministic Maglev
table), so in practice equal code produces equal artifacts; the bands
absorb cross-version JAX drift without letting a real regression through.

    python benchmarks/compare.py BENCH_pipeline.json BENCH_chain.json
    python benchmarks/compare.py --baselines benchmarks/baselines BENCH_*.json

Exit codes: 0 ok, 1 metric regression, 2 schema/IO mismatch.
"""
from __future__ import annotations

import argparse
import os
import re
import sys

try:
    from benchmarks.artifacts import (BenchArtifactError, load_bench_json,
                                      row_map)
except ImportError:  # run as a script: benchmarks/ itself is on sys.path
    from artifacts import BenchArtifactError, load_bench_json, row_map

DEFAULT_BASELINES = os.path.join(os.path.dirname(__file__), "baselines")

# (name regex, rtol, atol); rtol None = not gated.  First match wins.
# Timing patterns are anchored to full path segments — an unanchored
# "wall" would silently exempt any future "firewall" metric from the gate.
TOLERANCES: list[tuple[str, float | None, float]] = [
    (r"(/pps$|/wall_s$|/speedup$|_s$)", None, 0.0),
    (r"identical", 0.0, 0.0),
    (r"(gain|saving|reduction|delta|uplift)", 0.08, 0.02),
    (r"", 0.05, 0.0),
]


def tolerance_for(name: str) -> tuple[float | None, float]:
    for pat, rtol, atol in TOLERANCES:
        if re.search(pat, name):
            return rtol, atol
    raise AssertionError("unreachable: catch-all tolerance")


def _is_number(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def compare_rows(baseline: dict, candidate: dict) -> list[str]:
    """Per-row diffs between two loaded artifacts; empty list = pass."""
    problems = []
    base_rows, cand_rows = row_map(baseline), row_map(candidate)
    for name, brow in base_rows.items():
        if name not in cand_rows:
            problems.append(f"MISSING  {name}: in baseline, not in candidate")
            continue
        bval, cval = brow["value"], cand_rows[name]["value"]
        rtol, atol = tolerance_for(name)
        if rtol is None:
            continue
        if _is_number(bval) and _is_number(cval):
            lim = max(rtol * abs(bval), atol)
            if abs(cval - bval) > lim:
                problems.append(
                    f"DRIFT    {name}: baseline={bval} candidate={cval} "
                    f"(|delta|={abs(cval - bval):.6g} > tol={lim:.6g})")
        elif bval != cval:
            problems.append(
                f"MISMATCH {name}: baseline={bval!r} candidate={cval!r}")
    for name in sorted(set(cand_rows) - set(base_rows)):
        problems.append(
            f"NEW      {name}: not in baseline (regenerate baselines "
            f"to start gating it)")
    return problems


def compare_files(baseline_path: str, candidate_path: str) -> list[str]:
    baseline = load_bench_json(baseline_path)
    candidate = load_bench_json(candidate_path)
    if baseline["bench"] != candidate["bench"]:
        return [f"MISMATCH bench name: baseline={baseline['bench']!r} "
                f"candidate={candidate['bench']!r}"]
    return compare_rows(baseline, candidate)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("candidates", nargs="+", metavar="BENCH_JSON",
                    help="candidate artifacts written by this commit's "
                         "bench runs")
    ap.add_argument("--baselines", default=DEFAULT_BASELINES,
                    help="directory of committed reference artifacts "
                         "(matched by basename)")
    args = ap.parse_args(argv)

    failed = False
    for cand in args.candidates:
        base = os.path.join(args.baselines, os.path.basename(cand))
        try:
            problems = compare_files(base, cand)
        except BenchArtifactError as e:
            print(f"compare: {e}", file=sys.stderr)
            return 2
        gating = [p for p in problems if not p.startswith("NEW")]
        label = "FAIL" if gating else "ok"
        print(f"[{label}] {cand} vs {base}: "
              f"{len(gating)} regression(s)")
        for p in problems:
            print(f"  {p}")
        failed |= bool(gating)
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
