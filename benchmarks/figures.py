"""One benchmark per paper table/figure (Figs. 7-16, Table 1).

Each function returns a list of CSV rows ``(name, value, derived)`` and
prints a small table; ``benchmarks/run.py`` drives them all.  The analytic
link/PCIe/CPU model (switchsim.perfmodel) provides rate curves; eviction /
explicit-drop dynamics additionally run the *real* core state machine
(switchsim.simulate).  Paper-reported values are included in the output for
side-by-side comparison; EXPERIMENTS.md discusses the deltas.

Run as a script, this module is the *consumer* of the per-commit
``BENCH_*.json`` artifacts (benchmarks/artifacts.py schema) written by
``bench_pipeline.py --json`` / ``bench_hostmodel.py --json`` /
``bench_chain.py --json``: it re-renders their rows without re-running any
simulation, and exits non-zero on a missing or malformed artifact instead
of silently rendering nothing.  When a ``chain`` artifact is present (or
``--require-chain`` demands one) it additionally renders the §7 chain
table — and because that table *references* specific scenario rows, a row
missing from the artifact is a hard error (exit 2), not a silently
shorter table:

    PYTHONPATH=src python benchmarks/figures.py BENCH_pipeline.json BENCH_chain.json
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.artifacts import (BenchArtifactError, load_bench_json,
                                      row_map)
except ImportError:  # run as a script: benchmarks/ itself is on sys.path
    from artifacts import BenchArtifactError, load_bench_json, row_map

from repro.core.park import ParkConfig
from repro.nf.chain import Chain
from repro.nf.firewall import Firewall
from repro.nf.macswap import NF_HEAVY, NF_LIGHT, NF_MEDIUM, MacSwap
from repro.nf.maglev import MaglevLB
from repro.nf.nat import Nat
from repro.switchsim import resources
from repro.switchsim.perfmodel import (ServerModel, digest, evaluate,
                                       peak_goodput)
from repro.switchsim.simulate import simulate
from repro.traffic.generator import enterprise, fixed

FW1 = [46.0]                  # 1-rule firewall (2-NF chain, §6.1)
FW20 = [160.0]                # 20-rule firewall (3-NF chain)
NAT = [80.0]
LB = [120.0]
CHAIN2 = FW1 + NAT            # FW -> NAT
CHAIN3 = FW20 + NAT + LB      # FW -> NAT -> LB


def fig7_goodput_latency_10ge():
    """Fig. 7: FW->NAT->LB on 10GE, enterprise traffic: goodput + latency vs
    send rate; paper: +13% peak goodput, no latency penalty."""
    m = ServerModel(link_gbps=10.0)
    wl = enterprise()
    rows = []
    d_base = digest(wl.sizes, wl.probs, 160, 160, False)
    d_park = digest(wl.sizes, wl.probs, 160, 160, True)
    for rate in (2, 4, 6, 8, 9, 10, 11, 12):
        b = evaluate(m, d_base, CHAIN3, rate)
        p = evaluate(m, d_park, CHAIN3, rate)
        rows.append((f"fig7/goodput@{rate}G/base", round(b.goodput_gbps, 4),
                     f"lat_us={b.latency_us:.1f},drop={b.drop_rate:.4f}"))
        rows.append((f"fig7/goodput@{rate}G/park", round(p.goodput_gbps, 4),
                     f"lat_us={p.latency_us:.1f},drop={p.drop_rate:.4f}"))
    base = peak_goodput(m, d_base, CHAIN3)
    park = peak_goodput(m, d_park, CHAIN3, parking=True,
                        table_capacity=24_000)
    gain = park.goodput_gbps / base.goodput_gbps - 1
    rows.append(("fig7/peak_gain_pct", round(100 * gain, 2),
                 "paper=13%"))
    return rows


def fig8_goodput_packet_sizes():
    """Fig. 8: goodput vs fixed packet size (40GE): paper band 10-36%."""
    m = ServerModel(link_gbps=40.0)
    rows = []
    for chain, cname in ((FW1, "FW"), (NAT, "NAT"), (CHAIN2, "FW-NAT")):
        for size in (256, 384, 512, 1024, 1492):
            base = peak_goodput(m, digest([size], [1.0], 160, 160, False),
                                chain)
            park = peak_goodput(m, digest([size], [1.0], 160, 160, True),
                                chain, parking=True, table_capacity=24_000)
            gain = 100 * (park.goodput_gbps / base.goodput_gbps - 1)
            rows.append((f"fig8/{cname}@{size}B/gain_pct", round(gain, 2),
                         f"base={base.goodput_gbps:.2f}G,"
                         f"park={park.goodput_gbps:.2f}G,"
                         f"bottleneck={park.bottleneck}"))
    return rows


def fig9_pcie_utilization():
    """Fig. 9: PCIe bus utilization vs packet size; paper: -2..-58%."""
    m = ServerModel(link_gbps=40.0)
    rows = []
    for size in (256, 384, 512, 1024, 1492):
        d_base = digest([size], [1.0], 160, 160, False)
        d_park = digest([size], [1.0], 160, 160, True)
        # compare at the same healthy send rate (baseline's peak)
        base = peak_goodput(m, d_base, CHAIN2)
        park = evaluate(m, d_park, CHAIN2, base.send_gbps)
        red = 100 * (1 - park.pcie_gbps_used / base.pcie_gbps_used)
        rows.append((f"fig9/pcie_reduction@{size}B_pct", round(red, 2),
                     f"base={base.pcie_gbps_used:.2f}G,"
                     f"park={park.pcie_gbps_used:.2f}G,paper=2..58%"))
    return rows


def fig10_11_multi_server():
    """Figs. 10/11: 8 NF servers (2 per pipe), 384B packets: consistent
    per-server gain; paper: avg +31.2% goodput, -9.4% latency."""
    m = ServerModel(link_gbps=40.0)
    d_base = digest([384], [1.0], 160, 160, False)
    d_park = digest([384], [1.0], 160, 160, True)
    # static slicing: 40% of pipe SRAM split between 2 servers per pipe
    # (the inversion now places whole SRAM blocks per slice, like Table 1)
    cfg = ParkConfig()
    slots = resources.capacity_for_memory_fraction(0.40, cfg, nf_servers=2)
    rows = []
    gains = []
    lat = []
    for server in range(8):
        base = peak_goodput(m, d_base, [30.0])  # MAC swapper
        park = peak_goodput(m, d_park, [30.0], parking=True,
                            table_capacity=slots)
        gains.append(100 * (park.goodput_gbps / base.goodput_gbps - 1))
        lat.append(100 * (1 - park.latency_us / base.latency_us))
        rows.append((f"fig10/server{server + 1}/gain_pct",
                     round(gains[-1], 2),
                     f"slots={slots}"))
    rows.append(("fig10/avg_gain_pct", round(float(np.mean(gains)), 2),
                 "paper=31.22%"))
    rows.append(("fig11/avg_latency_saving_pct",
                 round(float(np.mean(lat)), 2), "paper=9.4%"))
    return rows


def fig12_eviction_explicit_drops():
    """Fig. 12: EXP={2,10} x explicit-drops on a dropping FW->NAT chain.
    Runs the REAL state machine; reports successful-split fraction (the
    goodput proxy: splits that survive to merge)."""
    key = jax.random.key(0)
    wl = enterprise()
    pkts = wl.make_batch(key, 1024, pmax=2048)
    rules = tuple(int(ip) for ip in
                  np.unique(np.asarray(pkts.src_ip))[:100].tolist())
    chain = Chain((Firewall(rules=rules), Nat()))
    rows = []
    for exp in (2, 10):
        for explicit in (False, True):
            cfg = ParkConfig(capacity=96, max_exp=exp, pmax=2048)
            res = simulate(cfg, chain, pkts, window=2, chunk=64,
                           explicit_drops=explicit)
            c = res.counters
            label = f"exp{exp}/{'explicit' if explicit else 'no_explicit'}"
            rows.append((f"fig12/{label}/splits", c["splits"],
                         f"merges={c['merges']},"
                         f"premature={c['premature_evictions']},"
                         f"skip_occupied={c['skip_occupied']},"
                         f"explicit_drops={c['explicit_drops']}"))
    return rows


def fig13_recirculation():
    """Fig. 13: recirculation (352B parked, one extra pass per wide packet)
    on 10GE FW->NAT->LB; paper: +28% (vs +13% without).  The stateful-engine
    counterpart (table-occupancy sweep, measured recirculations and budget
    drops) is ``benchmarks/bench_pipeline.py --recirc``."""
    m = ServerModel(link_gbps=10.0)
    wl = enterprise()
    d_base = digest(wl.sizes, wl.probs, 160, 160, False)
    # pass_bytes=160: one traversal parks 160B, packets parking more take
    # one recirculation pass -> expected-passes latency term in evaluate().
    d_recirc = digest(wl.sizes, wl.probs, 352, 160, True, pass_bytes=160)
    base = peak_goodput(m, d_base, CHAIN3)
    park = peak_goodput(m, d_recirc, CHAIN3, parking=True,
                        table_capacity=10_000)
    gain = 100 * (park.goodput_gbps / base.goodput_gbps - 1)
    return [("fig13/recirc_gain_pct", round(gain, 2),
             f"paper=28% (model is link-bound: see EXPERIMENTS.md), "
             f"recirc_per_pkt={d_recirc.recirc_per_pkt:.2f}, "
             f"lat_delta_us="
             f"{park.latency_us - base.latency_us:.2f}")]


def fig14_reserved_memory():
    """Fig. 14: peak eviction-free goodput vs reserved switch memory %."""
    m = ServerModel(link_gbps=40.0)
    d_park = digest([384], [1.0], 160, 160, True)
    rows = []
    cfg = ParkConfig()
    for frac in (0.05, 0.11, 0.17, 0.21, 0.26):
        slots = resources.capacity_for_memory_fraction(frac, cfg)
        park = peak_goodput(m, d_park, CHAIN2, parking=True,
                            table_capacity=slots, max_exp=1)
        rows.append((f"fig14/goodput@{int(frac * 100)}pct_mem",
                     round(park.goodput_gbps, 3),
                     f"slots={slots},bottleneck={park.bottleneck}"))
    return rows


def fig15_nf_cycles():
    """Fig. 15: goodput gain for NF-Light/Medium/Heavy x packet size."""
    m = ServerModel(link_gbps=40.0)
    rows = []
    for cyc, cname in ((NF_LIGHT, "light"), (NF_MEDIUM, "medium"),
                       (NF_HEAVY, "heavy")):
        for size in (256, 512, 1024, 1492):
            base = peak_goodput(m, digest([size], [1.0], 160, 160, False),
                                [cyc])
            park = peak_goodput(m, digest([size], [1.0], 160, 160, True),
                                [cyc], parking=True, table_capacity=24_000)
            gain = 100 * (park.goodput_gbps / base.goodput_gbps - 1)
            rows.append((f"fig15/{cname}@{size}B/gain_pct", round(gain, 2),
                         f"bottleneck={base.bottleneck}->{park.bottleneck}"))
    return rows


def fig16_small_packet_latency():
    """Fig. 16: 512B FW->NAT: goodput + latency across send rates; latency
    spikes only past baseline saturation."""
    m = ServerModel(link_gbps=40.0)
    d_base = digest([512], [1.0], 160, 160, False)
    d_park = digest([512], [1.0], 160, 160, True)
    rows = []
    for rate in (10, 20, 30, 33.6, 36, 40):
        b = evaluate(m, d_base, CHAIN2, rate)
        p = evaluate(m, d_park, CHAIN2, rate)
        rows.append((f"fig16/@{rate}G/base_lat_us", round(b.latency_us, 2),
                     f"goodput={b.goodput_gbps:.3f}G"))
        rows.append((f"fig16/@{rate}G/park_lat_us", round(p.latency_us, 2),
                     f"goodput={p.goodput_gbps:.3f}G"))
    return rows


def table1_resources():
    """Table 1: Tofino resource utilization (model)."""
    rows = []
    cfg = ParkConfig(capacity=24_000)
    for servers, paper_avg, paper_peak in ((1, 25.94, 33.75), (2, 38.23, 48.75)):
        u = resources.utilization(cfg, nf_servers=servers)
        rows.append((f"table1/sram_avg_pct/{4 * servers}servers",
                     round(u.sram_avg_pct, 2), f"paper={paper_avg}%"))
        rows.append((f"table1/sram_peak_pct/{4 * servers}servers",
                     round(u.sram_peak_pct, 2), f"paper={paper_peak}%"))
    u = resources.utilization(ParkConfig(capacity=24_000), nf_servers=1)
    rows.append(("table1/phv_pct", round(u.phv_pct, 2), "paper=37.65%"))
    rows.append(("table1/vliw_pct", round(u.vliw_pct, 2), "paper=14.58%"))
    return rows


ALL_FIGURES = [
    fig7_goodput_latency_10ge,
    fig8_goodput_packet_sizes,
    fig9_pcie_utilization,
    fig10_11_multi_server,
    fig12_eviction_explicit_drops,
    fig13_recirculation,
    fig14_reserved_memory,
    fig15_nf_cycles,
    fig16_small_packet_latency,
    table1_resources,
]


# The §7 chain table references these *measured* scenario rows of the
# ``chain`` artifact (written by both bench_chain.py and the run.py
# matrix driver); the uplift column is derived from them.  A referenced
# row absent from the artifact is a hard error — the consume path must
# not render a silently thinner table (the pre-scenario-matrix
# behaviour).
SEC7_CHAIN_TABLE = [
    ("datacenter", "chain/datacenter_base/goodput_gain",
     "chain/datacenter_recirc/goodput_gain"),
    ("enterprise", "chain/enterprise_base/goodput_gain",
     "chain/enterprise_recirc/goodput_gain"),
]


def sec7_chain_table(payload: dict) -> list[str]:
    """Render the §7 FW->NAT->LB table from a ``chain`` artifact.

    Raises BenchArtifactError when any referenced scenario row is absent.
    """
    rows = row_map(payload)

    def need(name):
        if name not in rows:
            raise BenchArtifactError(
                f"chain artifact is missing referenced scenario row "
                f"{name!r} (have {len(rows)} rows)")
        return rows[name]["value"]

    lines = [
        "# §7 chain table: FW->NAT->LB goodput gain "
        "(paper: +13%, +28% with recirculation on DC traffic)",
        "# workload    | gain      | gain+recirc | uplift",
    ]
    for label, base_row, rec_row in SEC7_CHAIN_TABLE:
        base, rec = need(base_row), need(rec_row)
        lines.append(f"# {label:<11} | {100 * base:8.2f}% | "
                     f"{100 * rec:10.2f}% | {100 * (rec - base):+.2f}%")
    return lines


def main(argv=None) -> None:
    """Render benchmark-trajectory rows from BENCH_*.json artifacts.

    Consumes the artifacts the benches wrote (no simulation re-run);
    any missing or schema-violating file — or a chain artifact missing a
    row the §7 table references — is a hard error (exit 2), not a
    silently empty figure.
    """
    ap = argparse.ArgumentParser(
        description="Render the benchmark trajectory from BENCH_*.json "
                    "artifacts written by benchmarks/bench_*.py --json.")
    ap.add_argument("artifacts", nargs="+", metavar="BENCH_JSON",
                    help="paths to BENCH_*.json files")
    ap.add_argument("--require-chain", action="store_true",
                    help="fail unless a 'chain' artifact (the §7 table "
                         "source) is among the inputs")
    args = ap.parse_args(argv)
    try:
        payloads = [load_bench_json(p) for p in args.artifacts]
        chain_payloads = [p for p in payloads if p["bench"] == "chain"]
        if args.require_chain and not chain_payloads:
            raise BenchArtifactError(
                "no 'chain' artifact among the inputs (--require-chain)")
        chain_tables = [sec7_chain_table(p) for p in chain_payloads]
    except BenchArtifactError as e:
        print(f"figures: {e}", file=sys.stderr)
        raise SystemExit(2)
    print("name,value,derived")
    for payload in payloads:
        for row in payload["rows"]:
            derived = str(row.get("derived", "")).replace(",", ";")
            print(f"{row['name']},{row['value']},{derived}")
    for lines in chain_tables:
        for line in lines:
            print(line)
    for payload in payloads:
        for key, val in sorted(payload.get("summary", {}).items()):
            print(f"# {payload['bench']}/{key}: {val}")


if __name__ == "__main__":
    main()
