"""Multi-pipe scanned-engine benchmark: packets/sec and goodput gain.

Measures what the seed host-loop could not: the compiled engine's packet
rate at 1/2/4/8 pipes (the paper's ToR switch services up to 8 NF servers,
one per-port pipe each, §6.3.2) and the goodput gain realized on the
switch<->server links, both measured (byte counts from the simulation) and
predicted (the calibrated analytic model fed with the measured digest).

At 1 pipe it also verifies the engine is wire-identical to the seed Python
chunk loop on the same trace and reports the speedup over it.

Two effects worth knowing when reading the numbers:
  * pipes are vmapped — on a single CPU device they serialize, so wall-clock
    pps does NOT scale with pipe count here; the model-predicted aggregate
    goodput (``model_goodput_gbps``, per-port links and servers) is the
    multi-server scaling metric.  On parallel hardware the pipe axis maps to
    independent compute.
  * per-pipe NF state is replicated (each pipe fronts its own server), so a
    single pipe's NAT flow table runs hotter at high flow counts than split
    pipes.  NAT flow expiry (EXP-style, see ``nf/nat.py``) reclaims idle
    mappings, so ≥16k-flow single-pipe traces suffer only *transient* drops
    while slots age out — the permanent-drop skew the seed NAT had is gone,
    and ``goodput_gain`` is now drop-aware anyway (the baseline charges the
    return trip only for chain survivors; the old 2x-wire figure is
    reported as ``naive``).  The ``merges`` figure in the derived column
    still exposes residual churn drops.

``--recirc`` runs the paper §6.2.5 experiment instead: a table-occupancy
sweep comparing goodput gain with the recirculation lane off vs on
(retry + 352B rows under a recirculation-port budget), asserting the gain
is strictly higher at high occupancy — the Fig. 13 direction (13% -> 28%).

    PYTHONPATH=src python benchmarks/bench_pipeline.py --pipes 1 2 4 8
    PYTHONPATH=src python benchmarks/bench_pipeline.py --pipes 2 --tiny
    PYTHONPATH=src python benchmarks/bench_pipeline.py --recirc

Prints ``name,value,derived`` CSV rows like benchmarks/run.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.artifacts import write_bench_json
except ImportError:  # run as a script: benchmarks/ itself is on sys.path
    from artifacts import write_bench_json

from repro.core.packet import to_time_major, wire_bytes
from repro.hostmodel import HostModel, pcie_reduction
from repro.core.park import ParkConfig
from repro.nf.chain import Chain
from repro.nf.firewall import Firewall
from repro.nf.maglev import MaglevLB
from repro.nf.nat import Nat
from repro.switchsim import engine as E
from repro.switchsim import perfmodel as P
from repro.switchsim.simulate import simulate_loop
from repro.traffic.generator import enterprise, steer_pipes


def _cat(batches):
    return jax.tree.map(lambda *xs: jnp.concatenate(xs), *batches)


def _time(fn, repeats: int) -> float:
    fn()  # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def bench(pipes_list, n_pkts, chunk, window, capacity, pmax, repeats,
          verify: bool = True, explicit_drops: bool = False):
    wl = enterprise()
    pkts = wl.make_batch(jax.random.key(0), n_pkts, pmax=pmax)
    rules = tuple(int(ip) for ip in
                  np.unique(np.asarray(pkts.src_ip))[:20].tolist())
    chain = Chain((Firewall(rules=rules), Nat()))
    cfg = ParkConfig(capacity=capacity, max_exp=2, pmax=pmax)
    model = P.ServerModel()
    rows = []

    for n_pipes in pipes_list:
        shards, steer_stats = steer_pipes(pkts, n_pipes, chunk=chunk)
        traces = jax.tree.map(
            lambda a: a.reshape(
                (n_pipes, a.shape[1] // chunk, chunk) + a.shape[2:]), shards)

        def run(traces=traces):
            res = E.run_pipes(cfg, chain, traces, window=window,
                              explicit_drops=explicit_drops)
            jax.block_until_ready(res.merged.payload)
            return res

        res = run()
        dt = _time(run, repeats)
        pps = n_pkts / dt
        gain = E.goodput_gain(res)
        alive = sum(steer_stats["per_pipe_arrivals"]) \
            - steer_stats["overflow"]
        d = P.measured_digest(
            alive, res.wire_bytes, res.srv_fwd_bytes,
            res.counters["splits"] / max(alive, 1))
        base_d = P.TrafficDigest(d.mean_wire_bytes, d.mean_wire_bytes, 0.0)
        op_park = P.scale_pipes(
            P.peak_goodput(model, d, chain.cycle_costs(),
                           table_capacity=cfg.capacity, max_exp=cfg.max_exp,
                           parking=True), n_pipes)
        op_base = P.scale_pipes(
            P.peak_goodput(model, base_d, chain.cycle_costs()), n_pipes)
        model_gain = op_park.goodput_gbps / op_base.goodput_gbps - 1.0
        rows.append((
            f"pipeline/pipes{n_pipes}/pps", round(pps),
            f"wall_s={dt:.4f};splits={res.counters['splits']};"
            f"merges={res.counters['merges']};"
            f"premature={res.counters['premature_evictions']};"
            f"overflow={steer_stats['overflow']}"))
        rows.append((
            f"pipeline/pipes{n_pipes}/goodput_gain",
            round(gain["goodput_gain"], 4),
            f"link_byte_saving={gain['link_byte_saving']:.4f};"
            f"gain_naive={gain['goodput_gain_naive']:.4f};"
            f"model_peak_gain={model_gain:.4f};"
            f"model_goodput_gbps={op_park.goodput_gbps:.2f};"
            f"bottleneck={op_park.bottleneck};"
            f"pcie_reduction="
            f"{pcie_reduction(HostModel().link, res.telemetry):.4f}"))

    if verify and 1 in pipes_list:
        trace = to_time_major(pkts, chunk)
        eng = E.run_engine(cfg, chain, trace, window=window,
                           explicit_drops=explicit_drops, collect_sent=True)

        def run_loop():
            return simulate_loop(cfg, chain, pkts, window=window, chunk=chunk,
                                 explicit_drops=explicit_drops)

        loop_res = run_loop()
        dt_loop = _time(run_loop, max(1, repeats // 2))
        dt_eng = _time(
            lambda: jax.block_until_ready(
                E.run_engine(cfg, chain, trace, window=window,
                             explicit_drops=explicit_drops).merged.payload),
            repeats)
        got, gl = wire_bytes(
            jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]),
                         eng.merged))
        want, wl_ = wire_bytes(_cat(loop_res.merged))
        identical = (np.array_equal(np.asarray(got), np.asarray(want))
                     and np.array_equal(np.asarray(gl), np.asarray(wl_))
                     and eng.counters == loop_res.counters
                     and eng.srv_bytes == loop_res.srv_bytes
                     and eng.wire_bytes == loop_res.wire_bytes
                     and eng.ret_bytes == loop_res.ret_bytes)
        rows.append((
            "pipeline/engine_vs_seed_loop/identical", int(identical),
            f"speedup={dt_loop / dt_eng:.2f}x;"
            f"loop_s={dt_loop:.4f};engine_s={dt_eng:.4f}"))
        if not identical:
            raise SystemExit("engine output diverged from seed loop")
    return rows


def bench_recirc(n_pkts, chunk, window, pmax, recirc_frac=0.25):
    """Paper §6.2.5 / Fig. 13 direction on the stateful engine: sweep table
    occupancy (capacity vs the in-flight window) and compare goodput gain
    with the recirculation lane off vs on.  At high occupancy the lane must
    win strictly — retries rescue occupied-slot skips and second passes park
    up to 352B — or the bench exits non-zero.  Every recirculation-on run is
    also checked bit-identical against the host-loop oracle."""
    wl = enterprise()
    pkts = wl.make_batch(jax.random.key(0), n_pkts, pmax=pmax)
    rules = tuple(int(ip) for ip in
                  np.unique(np.asarray(pkts.src_ip))[:20].tolist())
    chain = Chain((Firewall(rules=rules), Nat(), MaglevLB()))
    trace = to_time_major(pkts, chunk)
    model = P.ServerModel()
    inflight = max(window, 1) * chunk
    sweeps = (("low", 8 * inflight), ("mid", inflight), ("high", inflight // 2))
    rows = []
    gains = {}
    for label, capacity in sweeps:
        res = {}
        for mode, on in (("off", False), ("on", True)):
            # max_exp=4 keeps the full table out of the premature-eviction
            # regime (the §6.2.5 experiment is occupancy pressure, not
            # eviction losses; EXP=2 at 100% occupancy evicts in-flight
            # payloads and drowns the recirculation signal in drops).
            cfg = ParkConfig(capacity=capacity, max_exp=4, pmax=pmax,
                             recirculation=on, recirc_frac=recirc_frac)
            res[mode] = E.run_engine(cfg, chain, trace, window=window)
            if on:
                loop = simulate_loop(cfg, chain, pkts, window=window,
                                     chunk=chunk)
                if not (res[mode].counters == loop.counters
                        and res[mode].srv_bytes == loop.srv_bytes
                        and res[mode].ret_bytes == loop.ret_bytes):
                    raise SystemExit(
                        f"recirc engine diverged from loop oracle @{label}")
        g = {m: E.goodput_gain(r) for m, r in res.items()}
        gains[label] = {m: g[m]["goodput_gain"] for m in g}
        c_on = res["on"].counters
        d = P.measured_digest(
            n_pkts, res["on"].wire_bytes, res["on"].srv_fwd_bytes,
            c_on["splits"] / max(n_pkts, 1),
            recirc_per_pkt=c_on["recirculations"] / max(n_pkts, 1))
        op = P.evaluate(model, d, chain.cycle_costs(), send_gbps=10.0)
        occ = res["on"].peak_occupancy
        rows.append((
            f"recirc/occ_{label}/gain_off",
            round(gains[label]["off"], 4),
            f"capacity={capacity};"
            f"peak_occ={res['off'].peak_occupancy};"
            f"skip_occupied={res['off'].counters['skip_occupied']}"))
        rows.append((
            f"recirc/occ_{label}/gain_on",
            round(gains[label]["on"], 4),
            f"capacity={capacity};peak_occ={occ};"
            f"recirculations={c_on['recirculations']};"
            f"budget_drops={c_on['recirc_budget_drops']};"
            f"skip_occupied={c_on['skip_occupied']};"
            f"premature={c_on['premature_evictions']};"
            f"model_lat_us={op.latency_us:.2f}"))
        rows.append((
            f"recirc/occ_{label}/gain_delta",
            round(gains[label]["on"] - gains[label]["off"], 4),
            f"recirc_frac={recirc_frac}"))
    if not gains["high"]["on"] > gains["high"]["off"]:
        raise SystemExit(
            f"recirculation gain not above baseline at high occupancy: "
            f"on={gains['high']['on']:.4f} off={gains['high']['off']:.4f}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pipes", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--packets", type=int, default=16384)
    ap.add_argument("--chunk", type=int, default=256)
    ap.add_argument("--window", type=int, default=2)
    ap.add_argument("--capacity", type=int, default=4096)
    ap.add_argument("--pmax", type=int, default=2048)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--recirc", action="store_true",
                    help="run the recirculation occupancy sweep "
                         "(paper §6.2.5) instead of the pipes sweep")
    ap.add_argument("--recirc-frac", type=float, default=0.25,
                    help="recirculation-port share of pipe capacity")
    ap.add_argument("--explicit-drops", action="store_true",
                    help="NF-dropped parked packets send OP=drop "
                         "notifications back to the switch (paper §6.2.4)")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the bit-identical check vs the seed loop")
    ap.add_argument("--json", metavar="PATH",
                    help="also write the BENCH json artifact here "
                         "(benchmarks/artifacts.py schema)")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: 512 packets, chunk 64, small table")
    args = ap.parse_args()
    if args.recirc:
        # the occupancy sweep owns these knobs; fail loudly rather than
        # silently ignoring an explicit flag
        ignored = [flag for flag, val, default in (
            ("--capacity", args.capacity, 4096),
            ("--repeats", args.repeats, 3),
            ("--no-verify", args.no_verify, False),
            ("--explicit-drops", args.explicit_drops, False),
        ) if val != default]
        if ignored:
            ap.error(f"--recirc does not take {', '.join(ignored)} "
                     f"(the sweep sets capacity per occupancy point and "
                     f"always verifies against the loop oracle)")
    if args.tiny:
        args.packets, args.chunk, args.capacity = 512, 64, 256
        args.pmax, args.repeats = 512, 1
    if args.packets % args.chunk:
        ap.error(f"--packets ({args.packets}) must be a multiple of "
                 f"--chunk ({args.chunk})")
    if args.recirc:
        rows = bench_recirc(args.packets, args.chunk, args.window,
                            args.pmax, recirc_frac=args.recirc_frac)
    else:
        rows = bench(args.pipes, args.packets, args.chunk, args.window,
                     args.capacity, args.pmax, args.repeats,
                     verify=not args.no_verify,
                     explicit_drops=args.explicit_drops)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value},{str(derived).replace(',', ';')}")
    if args.json:
        write_bench_json(args.json, "recirc" if args.recirc else "pipeline",
                         rows)


if __name__ == "__main__":
    main()
