"""Multi-pipe scanned-engine benchmark: packets/sec and goodput gain.

Measures what the seed host-loop could not: the compiled engine's packet
rate at 1/2/4/8 pipes (the paper's ToR switch services up to 8 NF servers,
one per-port pipe each, §6.3.2) and the goodput gain realized on the
switch<->server links, both measured (byte counts from the simulation) and
predicted (the calibrated analytic model fed with the measured digest).

Since the scenario-matrix refactor (DESIGN.md §8) the sweep itself —
expansion, trace steering, engine execution, per-point regrouping — is the
``repro.scenarios`` runner; this bench only defines its grid from the CLI
flags and formats the rows, so the pipes sweep here, the nightly matrix
and CI smokes all execute through the same code path.

At 1 pipe it also verifies the engine is wire-identical to the seed Python
chunk loop on the same trace and reports the speedup over it.

Two effects worth knowing when reading the numbers:
  * pipes are vmapped — on a single CPU device they serialize, so wall-clock
    pps does NOT scale with pipe count here; the model-predicted aggregate
    goodput (``model_goodput_gbps``, per-port links and servers) is the
    multi-server scaling metric.  On parallel hardware the pipe axis maps to
    independent compute.
  * per-pipe NF state is replicated (each pipe fronts its own server), so a
    single pipe's NAT flow table runs hotter at high flow counts than split
    pipes.  NAT flow expiry (EXP-style, see ``nf/nat.py``) reclaims idle
    mappings, so >=16k-flow single-pipe traces suffer only *transient* drops
    while slots age out; ``goodput_gain`` is drop-aware (the baseline
    charges the return trip only for chain survivors; the old 2x-wire
    figure is reported as ``naive``).

``--recirc`` runs the paper §6.2.5 experiment instead: a table-occupancy
sweep comparing goodput gain with the recirculation lane off vs on
(retry + 352B rows under a recirculation-port budget), asserting the gain
is strictly higher at high occupancy — the Fig. 13 direction (13% -> 28%).

``--backend`` makes the dataplane backend (repro.backend, DESIGN.md §9) a
sweep axis: one value runs the whole sweep on that backend (rows keep their
historical names, the artifact records the backend as provenance); several
values record ref-vs-Pallas throughput side by side (``pipes2`` next to
``pipes2_pallas_interpret``).  ``--oracle`` additionally verify_oracle's
every point — engine≡loop counters+telemetry on that point's backend.

``--devices`` runs the fabric scaling sweep instead (switchsim.fabric,
DESIGN.md §12): each pipes point is re-run with its pipe axis sharded over
every requested device count (1 is auto-included as the invariance
reference), timing rows land as ``fabric/pipes{p}_dev{d}/pps`` and every
device count's counters/telemetry/occupancy are asserted bit-identical to
the single-device run (``shard_invariance_identical`` rows; the bench
exits non-zero on any divergence).  ``--host-devices N`` applies the
SNIPPETS.md ``--xla_force_host_platform_device_count`` recipe via
``repro.distributed.force_host_devices`` before jax initializes, so
CPU-only hosts (CI included) exercise real multi-device sharding.

    PYTHONPATH=src python benchmarks/bench_pipeline.py --pipes 1 2 4 8
    PYTHONPATH=src python benchmarks/bench_pipeline.py --pipes 2 --tiny
    PYTHONPATH=src python benchmarks/bench_pipeline.py --recirc
    PYTHONPATH=src python benchmarks/bench_pipeline.py --pipes 1 2 \
        --backend ref pallas_interpret
    PYTHONPATH=src python benchmarks/bench_pipeline.py --pipes 2 --tiny \
        --backend pallas_interpret --oracle
    PYTHONPATH=src python benchmarks/bench_pipeline.py --pipes 8 \
        --host-devices 8 --devices 1 2 8 --oracle --json BENCH_fabric.json

Prints ``name,value,derived`` CSV rows like benchmarks/run.py.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.artifacts import write_bench_json
    from benchmarks.common import check_flags, make_parser, print_rows
except ImportError:  # run as a script: benchmarks/ itself is on sys.path
    from artifacts import write_bench_json
    from common import check_flags, make_parser, print_rows

import repro.scenarios as S
from repro.core.packet import to_time_major, wire_bytes
from repro.hostmodel import HostModel, pcie_reduction
from repro.switchsim import engine as E
from repro.switchsim import perfmodel as P
from repro.switchsim.simulate import simulate_loop


def _cat(batches):
    return jax.tree.map(lambda *xs: jnp.concatenate(xs), *batches)


def _time(fn, repeats: int) -> float:
    fn()  # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def bench(pipes_list, n_pkts, chunk, window, capacity, pmax, repeats,
          verify: bool = True, explicit_drops: bool = False,
          backends=("ref",), oracle: bool = False):
    specs = S.pipeline_grid(pipes_list, packets=n_pkts, chunk=chunk,
                            window=window, pmax=pmax, capacity=capacity,
                            explicit_drops=explicit_drops, backends=backends)
    results = S.run_matrix(specs, time_runs=True, time_repeats=repeats)
    model = P.ServerModel()
    rows = []
    matrix = {s.name: s.as_dict() for s in specs}

    for spec, res in zip(specs, results):
        if oracle:
            S.verify_oracle(res)  # engine≡loop on this point's backend
        n_pipes = spec.pipes
        dt = res.wall_s
        pps = n_pkts / dt if dt else 0.0
        gain = res.gain
        cfg = spec.park_config()
        d = P.measured_digest(
            res.alive_offered, res.telemetry.wire_bytes,
            res.telemetry.to_server_bytes,
            res.counters["splits"] / max(res.alive_offered, 1))
        base_d = P.TrafficDigest(d.mean_wire_bytes, d.mean_wire_bytes, 0.0)
        op_park = P.scale_pipes(
            P.peak_goodput(model, d, res.nf_cycles,
                           table_capacity=cfg.capacity, max_exp=cfg.max_exp,
                           parking=True), n_pipes)
        op_base = P.scale_pipes(
            P.peak_goodput(model, base_d, res.nf_cycles), n_pipes)
        model_gain = op_park.goodput_gbps / op_base.goodput_gbps - 1.0
        rows.append((
            f"pipeline/{spec.name}/pps", round(pps),
            f"wall_s={dt:.4f};splits={res.counters['splits']};"
            f"merges={res.counters['merges']};"
            f"premature={res.counters['premature_evictions']};"
            f"overflow={res.steer_stats['overflow']};"
            f"backend={spec.backend}", spec.name))
        rows.append((
            f"pipeline/{spec.name}/goodput_gain",
            round(gain["goodput_gain"], 4),
            f"link_byte_saving={gain['link_byte_saving']:.4f};"
            f"gain_naive={gain['goodput_gain_naive']:.4f};"
            f"model_peak_gain={model_gain:.4f};"
            f"model_goodput_gbps={op_park.goodput_gbps:.2f};"
            f"bottleneck={op_park.bottleneck};"
            f"pcie_reduction="
            f"{pcie_reduction(HostModel().link, res.telemetry):.4f}",
            spec.name))

    if verify and 1 in pipes_list:
        for spec1 in [s for s in specs if s.pipes == 1]:
            pkts = S.make_packets(spec1)
            chain = S.build_chain(spec1, pkts)
            cfg = spec1.park_config()
            bk = spec1.backend_config()
            trace = to_time_major(pkts, chunk)
            eng = E.run_engine(cfg, chain, trace, window=window,
                               explicit_drops=explicit_drops, backend=bk,
                               collect_sent=True)

            def run_loop(cfg=cfg, chain=chain, pkts=pkts, bk=bk):
                return simulate_loop(cfg, chain, pkts, window=window,
                                     chunk=chunk,
                                     explicit_drops=explicit_drops,
                                     backend=bk)

            loop_res = run_loop()
            dt_loop = _time(run_loop, max(1, repeats // 2))
            dt_eng = _time(
                lambda cfg=cfg, chain=chain, trace=trace, bk=bk:
                jax.block_until_ready(
                    E.run_engine(cfg, chain, trace, window=window,
                                 explicit_drops=explicit_drops,
                                 backend=bk).merged.payload),
                repeats)
            got, gl = wire_bytes(
                jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]),
                             eng.merged))
            want, wl_ = wire_bytes(_cat(loop_res.merged))
            identical = (np.array_equal(np.asarray(got), np.asarray(want))
                         and np.array_equal(np.asarray(gl), np.asarray(wl_))
                         and eng.counters == loop_res.counters
                         and eng.telemetry == loop_res.telemetry)
            # legacy row name for a single-backend sweep so committed
            # baselines keep gating it; per-backend names when swept
            vname = ("pipeline/engine_vs_seed_loop/identical"
                     if len(backends) == 1 else
                     f"pipeline/engine_vs_seed_loop_{spec1.backend}"
                     f"/identical")
            rows.append((
                vname, int(identical),
                f"speedup={dt_loop / dt_eng:.2f}x;"
                f"loop_s={dt_loop:.4f};engine_s={dt_eng:.4f};"
                f"backend={spec1.backend}", spec1.name))
            if not identical:
                raise SystemExit(
                    f"engine output diverged from seed loop "
                    f"(backend={spec1.backend})")
    return rows, matrix


def bench_fabric(pipes_list, devices_list, n_pkts, chunk, window, capacity,
                 pmax, repeats, backends=("ref",), oracle: bool = False,
                 explicit_drops: bool = False):
    """Fabric scaling sweep (DESIGN.md §12): every pipes point re-run with
    its pipe axis sharded over each requested device count.

    Device count 1 is auto-included as the invariance reference even when
    not requested: shard-count invariance — bit-identical counters,
    telemetry, per-pipe peak occupancy and occupancy series across device
    counts — is the sweep's correctness claim, asserted here and emitted
    as exact-gated ``shard_invariance_identical`` rows.  Any divergence
    exits non-zero.  Timing rows (``fabric/.../pps``) record the scaling
    trajectory; ``devices_effective`` in the derived field exposes the
    guarded fallback (requested counts that didn't divide the pipe axis or
    exceeded visible devices ran replicated on one device).
    """
    from repro.switchsim import fabric
    devices_list = sorted(set(devices_list) | {1})
    specs = S.pipeline_grid(pipes_list, packets=n_pkts, chunk=chunk,
                            window=window, pmax=pmax, capacity=capacity,
                            explicit_drops=explicit_drops,
                            backends=backends, devices=devices_list)
    results = S.run_matrix(specs, time_runs=True, time_repeats=repeats)
    matrix = {s.name: s.as_dict() for s in specs}
    rows = []
    points: dict = {}  # (pipes, backend) -> [(spec, result)] in devices order
    for spec, res in zip(specs, results):
        if oracle:
            S.verify_oracle(res)  # engine≡loop per pipe, hence per shard
        eff = fabric.resolve_devices(spec.pipes, spec.devices)
        dt = res.wall_s
        rows.append((
            f"fabric/{spec.name}/pps", round(n_pkts / dt) if dt else 0,
            f"wall_s={dt:.4f};devices={spec.devices};"
            f"devices_effective={eff};pipes={spec.pipes};"
            f"backend={spec.backend}", spec.name))
        rows.append((
            f"fabric/{spec.name}/goodput_gain",
            round(res.gain["goodput_gain"], 4),
            f"link_byte_saving={res.gain['link_byte_saving']:.4f};"
            f"devices={spec.devices}", spec.name))
        points.setdefault((spec.pipes, spec.backend), []).append((spec, res))

    diverged = []
    for (pipes, _bk), group in sorted(points.items()):
        ref_spec, ref = group[0]  # devices=1 (devices_list is sorted)
        assert ref_spec.devices == 1
        label = ref_spec.name.rsplit("_dev", 1)[0]
        bad = []
        for spec, res in group[1:]:
            same = (
                res.counters == ref.counters
                and res.per_pipe_counters == ref.per_pipe_counters
                and res.telemetry == ref.telemetry
                and res.per_pipe_telemetry == ref.per_pipe_telemetry
                and res.nf_counters == ref.nf_counters
                and res.per_pipe_nf_counters == ref.per_pipe_nf_counters
                and res.per_pipe_peak_occupancy
                == ref.per_pipe_peak_occupancy
                and np.array_equal(np.asarray(res.per_pipe_occ_series),
                                   np.asarray(ref.per_pipe_occ_series)))
            if not same:
                bad.append(spec.name)
        rows.append((
            f"fabric/{label}/shard_invariance_identical", int(not bad),
            f"devices={'/'.join(str(s.devices) for s, _ in group)};"
            f"diverged={','.join(bad) or 'none'}", label))
        diverged.extend(bad)
    if diverged:
        raise SystemExit(
            f"shard-count invariance violated: {', '.join(diverged)} "
            f"diverged from the single-device reference")
    return rows, matrix


def bench_recirc(n_pkts, chunk, window, pmax, recirc_frac=0.25):
    """Paper §6.2.5 / Fig. 13 direction on the stateful engine: sweep table
    occupancy (capacity vs the in-flight window) and compare goodput gain
    with the recirculation lane off vs on.  At high occupancy the lane must
    win strictly — retries rescue occupied-slot skips and second passes park
    up to 352B — or the bench exits non-zero.  Every recirculation-on run is
    also checked against the host-loop oracle (counters + telemetry)."""
    specs = S.recirc_grid(packets=n_pkts, chunk=chunk, window=window,
                          pmax=pmax, recirc_frac=recirc_frac)
    results = {r.spec.name: r for r in S.run_matrix(specs)}
    model = P.ServerModel()
    matrix = {s.name: s.as_dict() for s in specs}
    rows = []
    gains = {}
    for label in ("low", "mid", "high"):
        off = results[f"occ_{label}_off"]
        on = results[f"occ_{label}_on"]
        capacity = off.spec.capacity
        S.verify_oracle(on)  # raises OracleMismatch on divergence
        gains[label] = {"off": off.gain["goodput_gain"],
                        "on": on.gain["goodput_gain"]}
        c_on = on.counters
        d = P.measured_digest(
            n_pkts, on.telemetry.wire_bytes, on.telemetry.to_server_bytes,
            c_on["splits"] / max(n_pkts, 1),
            recirc_per_pkt=c_on["recirculations"] / max(n_pkts, 1))
        op = P.evaluate(model, d, on.nf_cycles, send_gbps=10.0)
        rows.append((
            f"recirc/occ_{label}/gain_off",
            round(gains[label]["off"], 4),
            f"capacity={capacity};"
            f"peak_occ={off.peak_occupancy};"
            f"skip_occupied={off.counters['skip_occupied']}",
            off.spec.name))
        rows.append((
            f"recirc/occ_{label}/gain_on",
            round(gains[label]["on"], 4),
            f"capacity={capacity};peak_occ={on.peak_occupancy};"
            f"recirculations={c_on['recirculations']};"
            f"budget_drops={c_on['recirc_budget_drops']};"
            f"skip_occupied={c_on['skip_occupied']};"
            f"premature={c_on['premature_evictions']};"
            f"model_lat_us={op.latency_us:.2f}", on.spec.name))
        rows.append((
            f"recirc/occ_{label}/gain_delta",
            round(gains[label]["on"] - gains[label]["off"], 4),
            f"recirc_frac={recirc_frac}", None))
    if not gains["high"]["on"] > gains["high"]["off"]:
        raise SystemExit(
            f"recirculation gain not above baseline at high occupancy: "
            f"on={gains['high']['on']:.4f} off={gains['high']['off']:.4f}")
    return rows, matrix


def main() -> None:
    # shared flags (--tiny/--json/--no-verify/--oracle/--backend) come
    # from the common parent parser (benchmarks/common.py); this bench is
    # the one that sweeps multiple --backend values
    ap = make_parser(__doc__)
    ap.add_argument("--pipes", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--packets", type=int, default=16384)
    ap.add_argument("--chunk", type=int, default=256)
    ap.add_argument("--window", type=int, default=2)
    ap.add_argument("--capacity", type=int, default=4096)
    ap.add_argument("--pmax", type=int, default=2048)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--devices", type=int, nargs="+", default=[1],
                    help="fabric scaling sweep (DESIGN.md §12): shard each "
                         "pipes point over these device counts (1 is "
                         "auto-included as the invariance reference); "
                         "emits fabric/* rows instead of pipeline/*")
    ap.add_argument("--host-devices", type=int, default=0, metavar="N",
                    help="force the CPU platform to expose N devices "
                         "(repro.distributed.force_host_devices; must run "
                         "before jax initializes, which this flag "
                         "guarantees by applying it first)")
    ap.add_argument("--recirc", action="store_true",
                    help="run the recirculation occupancy sweep "
                         "(paper §6.2.5) instead of the pipes sweep")
    ap.add_argument("--recirc-frac", type=float, default=0.25,
                    help="recirculation-port share of pipe capacity")
    ap.add_argument("--explicit-drops", action="store_true",
                    help="NF-dropped parked packets send OP=drop "
                         "notifications back to the switch (paper §6.2.4)")
    args = ap.parse_args()
    check_flags(ap, args)
    backends = args.backend or ["ref"]
    if args.host_devices:
        # before ANY jax device use — force_host_devices raises if too late
        from repro.distributed import force_host_devices
        force_host_devices(args.host_devices)
    fabric_sweep = args.devices != [1]
    if args.recirc:
        # the occupancy sweep owns these knobs; fail loudly rather than
        # silently ignoring an explicit flag
        ignored = [flag for flag, val, default in (
            ("--capacity", args.capacity, 4096),
            ("--repeats", args.repeats, 3),
            ("--no-verify", args.no_verify, False),
            ("--explicit-drops", args.explicit_drops, False),
            ("--backend", args.backend, None),
            ("--oracle", args.oracle, False),
            ("--devices", tuple(args.devices), (1,)),
        ) if val != default]
        if ignored:
            ap.error(f"--recirc does not take {', '.join(ignored)} "
                     f"(the sweep sets capacity per occupancy point and "
                     f"always verifies against the loop oracle)")
    if fabric_sweep:
        if len(backends) > 1:
            ap.error("--devices sweeps take a single --backend (the "
                     "invariance reference is per (pipes, backend) point)")
        if args.no_verify:
            ap.error("--no-verify only applies to the pipes sweep's "
                     "seed-loop check; the fabric sweep's invariance "
                     "check is not optional")
    if args.tiny:
        args.packets, args.chunk, args.capacity = 512, 64, 256
        args.pmax, args.repeats = 512, 1
    if args.packets % args.chunk:
        ap.error(f"--packets ({args.packets}) must be a multiple of "
                 f"--chunk ({args.chunk})")
    if args.recirc:
        rows, matrix = bench_recirc(args.packets, args.chunk, args.window,
                                    args.pmax, recirc_frac=args.recirc_frac)
    elif fabric_sweep:
        rows, matrix = bench_fabric(args.pipes, args.devices, args.packets,
                                    args.chunk, args.window, args.capacity,
                                    args.pmax, args.repeats,
                                    backends=backends,
                                    oracle=args.oracle,
                                    explicit_drops=args.explicit_drops)
    else:
        rows, matrix = bench(args.pipes, args.packets, args.chunk,
                             args.window, args.capacity, args.pmax,
                             args.repeats, verify=not args.no_verify,
                             explicit_drops=args.explicit_drops,
                             backends=backends, oracle=args.oracle)
    print_rows(rows)
    if args.json:
        # single-backend runs record their backend as artifact provenance
        # (compare.py uses it to match baselines per backend); resolved to
        # what actually ran, so "auto" can never mask a platform difference
        backend = None
        if not args.recirc and len(backends) == 1:
            from repro.backend import as_config
            backend = as_config(backends[0]).concrete().default
        family = ("recirc" if args.recirc
                  else "fabric" if fabric_sweep else "pipeline")
        write_bench_json(args.json, family, rows, matrix=matrix,
                         backend=backend)


if __name__ == "__main__":
    main()
