"""Multi-pipe scanned-engine benchmark: packets/sec and goodput gain.

Measures what the seed host-loop could not: the compiled engine's packet
rate at 1/2/4/8 pipes (the paper's ToR switch services up to 8 NF servers,
one per-port pipe each, §6.3.2) and the goodput gain realized on the
switch<->server links, both measured (byte counts from the simulation) and
predicted (the calibrated analytic model fed with the measured digest).

At 1 pipe it also verifies the engine is wire-identical to the seed Python
chunk loop on the same trace and reports the speedup over it.

Two effects worth knowing when reading the numbers:
  * pipes are vmapped — on a single CPU device they serialize, so wall-clock
    pps does NOT scale with pipe count here; the model-predicted aggregate
    goodput (``model_goodput_gbps``, per-port links and servers) is the
    multi-server scaling metric.  On parallel hardware the pipe axis maps to
    independent compute.
  * per-pipe NF state is replicated (each pipe fronts its own server), so a
    single pipe's NAT flow table saturates at high flow counts while split
    pipes do not — chain drops then skew the measured byte saving (dropped
    packets never make the return trip).  The ``merges`` figure in the
    derived column exposes this.

    PYTHONPATH=src python benchmarks/bench_pipeline.py --pipes 1 2 4 8
    PYTHONPATH=src python benchmarks/bench_pipeline.py --pipes 2 --tiny

Prints ``name,value,derived`` CSV rows like benchmarks/run.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packet import to_time_major, wire_bytes
from repro.core.park import ParkConfig
from repro.nf.chain import Chain
from repro.nf.firewall import Firewall
from repro.nf.nat import Nat
from repro.switchsim import engine as E
from repro.switchsim import perfmodel as P
from repro.switchsim.simulate import simulate_loop
from repro.traffic.generator import enterprise, steer_pipes


def _cat(batches):
    return jax.tree.map(lambda *xs: jnp.concatenate(xs), *batches)


def _time(fn, repeats: int) -> float:
    fn()  # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def bench(pipes_list, n_pkts, chunk, window, capacity, pmax, repeats,
          verify: bool = True, explicit_drops: bool = False):
    wl = enterprise()
    pkts = wl.make_batch(jax.random.key(0), n_pkts, pmax=pmax)
    rules = tuple(int(ip) for ip in
                  np.unique(np.asarray(pkts.src_ip))[:20].tolist())
    chain = Chain((Firewall(rules=rules), Nat()))
    cfg = ParkConfig(capacity=capacity, max_exp=2, pmax=pmax)
    model = P.ServerModel()
    rows = []

    for n_pipes in pipes_list:
        shards, steer_stats = steer_pipes(pkts, n_pipes, chunk=chunk)
        traces = jax.tree.map(
            lambda a: a.reshape(
                (n_pipes, a.shape[1] // chunk, chunk) + a.shape[2:]), shards)

        def run(traces=traces):
            res = E.run_pipes(cfg, chain, traces, window=window,
                              explicit_drops=explicit_drops)
            jax.block_until_ready(res.merged.payload)
            return res

        res = run()
        dt = _time(run, repeats)
        pps = n_pkts / dt
        gain = E.goodput_gain(res)
        alive = sum(steer_stats["per_pipe_arrivals"]) \
            - steer_stats["overflow"]
        d = P.measured_digest(
            alive, res.wire_bytes, res.srv_fwd_bytes,
            res.counters["splits"] / max(alive, 1))
        base_d = P.TrafficDigest(d.mean_wire_bytes, d.mean_wire_bytes, 0.0)
        op_park = P.scale_pipes(
            P.peak_goodput(model, d, chain.cycle_costs(),
                           table_capacity=cfg.capacity, max_exp=cfg.max_exp,
                           parking=True), n_pipes)
        op_base = P.scale_pipes(
            P.peak_goodput(model, base_d, chain.cycle_costs()), n_pipes)
        model_gain = op_park.goodput_gbps / op_base.goodput_gbps - 1.0
        rows.append((
            f"pipeline/pipes{n_pipes}/pps", round(pps),
            f"wall_s={dt:.4f};splits={res.counters['splits']};"
            f"merges={res.counters['merges']};"
            f"premature={res.counters['premature_evictions']};"
            f"overflow={steer_stats['overflow']}"))
        rows.append((
            f"pipeline/pipes{n_pipes}/goodput_gain",
            round(gain["goodput_gain"], 4),
            f"link_byte_saving={gain['link_byte_saving']:.4f};"
            f"model_peak_gain={model_gain:.4f};"
            f"model_goodput_gbps={op_park.goodput_gbps:.2f};"
            f"bottleneck={op_park.bottleneck}"))

    if verify and 1 in pipes_list:
        trace = to_time_major(pkts, chunk)
        eng = E.run_engine(cfg, chain, trace, window=window,
                           explicit_drops=explicit_drops, collect_sent=True)

        def run_loop():
            return simulate_loop(cfg, chain, pkts, window=window, chunk=chunk,
                                 explicit_drops=explicit_drops)

        loop_res = run_loop()
        dt_loop = _time(run_loop, max(1, repeats // 2))
        dt_eng = _time(
            lambda: jax.block_until_ready(
                E.run_engine(cfg, chain, trace, window=window,
                             explicit_drops=explicit_drops).merged.payload),
            repeats)
        got, gl = wire_bytes(
            jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]),
                         eng.merged))
        want, wl_ = wire_bytes(_cat(loop_res.merged))
        identical = (np.array_equal(np.asarray(got), np.asarray(want))
                     and np.array_equal(np.asarray(gl), np.asarray(wl_))
                     and eng.counters == loop_res.counters
                     and eng.srv_bytes == loop_res.srv_bytes
                     and eng.wire_bytes == loop_res.wire_bytes)
        rows.append((
            "pipeline/engine_vs_seed_loop/identical", int(identical),
            f"speedup={dt_loop / dt_eng:.2f}x;"
            f"loop_s={dt_loop:.4f};engine_s={dt_eng:.4f}"))
        if not identical:
            raise SystemExit("engine output diverged from seed loop")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pipes", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--packets", type=int, default=16384)
    ap.add_argument("--chunk", type=int, default=256)
    ap.add_argument("--window", type=int, default=2)
    ap.add_argument("--capacity", type=int, default=4096)
    ap.add_argument("--pmax", type=int, default=2048)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--explicit-drops", action="store_true",
                    help="NF-dropped parked packets send OP=drop "
                         "notifications back to the switch (paper §6.2.4)")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the bit-identical check vs the seed loop")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: 512 packets, chunk 64, small table")
    args = ap.parse_args()
    if args.tiny:
        args.packets, args.chunk, args.capacity = 512, 64, 256
        args.pmax, args.repeats = 512, 1
    if args.packets % args.chunk:
        ap.error(f"--packets ({args.packets}) must be a multiple of "
                 f"--chunk ({args.chunk})")
    rows = bench(args.pipes, args.packets, args.chunk, args.window,
                 args.capacity, args.pmax, args.repeats,
                 verify=not args.no_verify,
                 explicit_drops=args.explicit_drops)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value},{str(derived).replace(',', ';')}")


if __name__ == "__main__":
    main()
