"""Streaming steady-state benchmark: constant-memory long-haul runs
(DESIGN.md §13).

The materialized benches top out at 16384 packets per run — the whole
trace, its merged output and the per-step ys must fit in memory at once.
This bench drives the streaming engine (``switchsim.stream.run_stream``)
over a ``SyntheticSource`` at least **16x** that size (default 262144
packets; the nightly ladder runs >= 1e6) with a diurnal load profile and a
million-flow pool, and reports what only a steady-state run can:

  * **throughput** — steady-state packets/second through the donated-carry
    segment program (compiles excluded: the warm-up run compiles both the
    steady segment and the drain-pad shapes);
  * **tail latency** — p50/p99/p999 sojourn time from the deterministic
    reservoir sample (integer-ns model, see switchsim/stream.py);
  * **memory** — peak RSS, and the RSS growth between a short
    multi-segment run (steady-state buffers already allocated; on CPU the
    donated inputs are copied, so ~2 segments are transiently live) and
    the full run.  Constant memory means running 8-16x more segments
    grows RSS by ~nothing — far below materializing the full trace;
    ``constant_memory_ok`` is the gated verdict.

``--oracle`` additionally replays the first segments against the
materialized engine (``stream.replay_oracle``) and emits the bit-exactness
row compare.py gates exactly.

    PYTHONPATH=src python benchmarks/bench_streaming.py
    PYTHONPATH=src python benchmarks/bench_streaming.py --tiny --oracle \
        --json BENCH_streaming.json
    PYTHONPATH=src python benchmarks/bench_streaming.py --steps 4096  # nightly

Tiny geometry: 32 steps x chunk 64 (2048 packets), segment 8, reservoir
512, capacity 256 — the CI smoke whose artifact is the committed baseline.
"""
from __future__ import annotations

import resource
import time

try:
    from benchmarks.artifacts import write_bench_json
    from benchmarks.common import (check_flags, make_parser, print_rows,
                                   single_backend)
except ImportError:  # run as a script: benchmarks/ itself is on sys.path
    from artifacts import write_bench_json
    from common import check_flags, make_parser, print_rows, single_backend

from repro.core.park import ParkConfig
from repro.nf.chain import Chain
from repro.nf.nat import Nat
from repro.switchsim.engine import goodput_gain_from_telemetry
from repro.switchsim.stream import replay_oracle, run_stream
from repro.traffic.stream import DiurnalLoad, SyntheticSource

# full-run geometry: 1024 steps x chunk 256 = 262144 packets, 16x the
# largest materialized bench (bench_pipeline: 16384)
FULL = dict(steps=1024, chunk=256, pmax=2048, capacity=4096, window=2,
            segment_len=128, reservoir=4096, flows=1_000_000,
            load_period=512)
TINY = dict(steps=32, chunk=64, pmax=512, capacity=256, window=2,
            segment_len=8, reservoir=512, flows=10_000, load_period=32)


def _rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _trace_mb(steps: int, chunk: int, pmax: int) -> float:
    """Rough footprint of materializing the whole trace (payload dominates)."""
    return steps * chunk * (pmax + 64) / (1024.0 * 1024.0)


def bench(g: dict, oracle: bool, backend=None):
    cfg = ParkConfig(capacity=g["capacity"], max_exp=2, pmax=g["pmax"],
                     recirculation=True, recirc_frac=0.25)
    chain = Chain((Nat(),))
    source = SyntheticSource(
        steps=g["steps"], chunk=g["chunk"], pmax=g["pmax"], seed=0,
        flows=g["flows"], load=DiurnalLoad(period=g["load_period"]))

    def run(steps):
        import dataclasses
        src = (source if steps == source.steps
               else dataclasses.replace(source, steps=steps))
        return run_stream(cfg, chain, src, window=g["window"],
                          segment_len=g["segment_len"],
                          reservoir=g["reservoir"], backend=backend)

    # warm-up over one segment: compiles the steady segment AND the drain
    # pad (pad geometry is steps-independent), so the timed run is pure
    # steady-state execution.  The source's own generator program is warmed
    # separately — its jit cache is per-instance.
    run(g["segment_len"])
    source.segment(0, g["segment_len"])
    # RSS baseline AFTER a short multi-segment run: the steady-state
    # working set (segment buffers, transient donation copies) is already
    # at its high-water mark, so the full run — 8-16x more segments —
    # must not grow RSS beyond allocator noise
    run(min(g["steps"], 4 * g["segment_len"]))
    rss_before = _rss_mb()
    t0 = time.perf_counter()
    res = run(g["steps"])
    wall = time.perf_counter() - t0
    rss_after = _rss_mb()

    packets = res.steps * g["chunk"]
    growth = rss_after - rss_before
    trace_mb = _trace_mb(g["steps"], g["chunk"], g["pmax"])
    # constant memory: the full run may not cost more than a fraction of
    # what materializing its trace would (generous floor for allocator
    # noise on small smokes)
    bound_mb = max(64.0, trace_mb / 8.0)
    const_ok = int(growth < bound_mb)
    lat = res.latency
    gain = goodput_gain_from_telemetry(res.telemetry)

    rows = [
        ("streaming/steady/packets", packets,
         f"steps={res.steps};chunk={g['chunk']};"
         f"segments={res.segments};segment_len={res.segment_len}", None),
        ("streaming/steady/pps", round(packets / wall),
         f"wall_s={wall:.3f};donated_carry=1", None),
        ("streaming/steady/wall_s", round(wall, 3),
         f"packets={packets}", None),
        ("streaming/steady/p50_us", lat.get("p50_us"),
         f"samples={lat['samples']};reservoir={lat['reservoir']}", None),
        ("streaming/steady/p99_us", lat.get("p99_us"),
         f"samples={lat['samples']}", None),
        ("streaming/steady/p999_us", lat.get("p999_us"),
         f"samples={lat['samples']}", None),
        ("streaming/steady/latency_samples", lat["samples"],
         f"reservoir={lat['reservoir']}", None),
        ("streaming/steady/peak_occupancy", res.peak_occupancy,
         f"capacity={g['capacity']}", None),
        ("streaming/steady/goodput_gain",
         round(gain["goodput_gain"], 4),
         f"wire_bytes={res.wire_bytes};srv_bytes={res.srv_bytes}", None),
        ("streaming/steady/peak_rss_mb", round(rss_after, 1),
         f"before={rss_before:.1f}", None),
        ("streaming/steady/rss_growth_mb", round(growth, 1),
         f"bound={bound_mb:.1f};materialized_trace={trace_mb:.1f}", None),
        ("streaming/steady/constant_memory_ok", const_ok,
         f"growth={growth:.1f}MB;bound={bound_mb:.1f}MB", None),
    ]
    if oracle:
        rep = replay_oracle(cfg, chain, source, window=g["window"],
                            segment_len=g["segment_len"], segments=4,
                            backend=backend)
        rows.append((
            "streaming/steady/replay_identical", 1,
            f"segments={rep['segments']};steps={rep['steps']};"
            f"counters+telemetry+nf+peak_occ bit-exact vs materialized",
            None))
    if not const_ok:
        raise SystemExit(
            f"constant-memory bound violated: RSS grew {growth:.1f} MB "
            f"over the full run (bound {bound_mb:.1f} MB; materializing "
            f"the trace would take ~{trace_mb:.1f} MB)")
    summary = dict(
        packets=packets, pps=round(packets / wall),
        p50_us=lat.get("p50_us"), p99_us=lat.get("p99_us"),
        p999_us=lat.get("p999_us"), peak_rss_mb=round(rss_after, 1),
        rss_growth_mb=round(growth, 1), constant_memory_ok=bool(const_ok),
        geometry={k: v for k, v in g.items()},
    )
    return rows, summary


def main() -> None:
    ap = make_parser(__doc__)
    ap.add_argument("--steps", type=int, default=None,
                    help="override the trace length in steps (nightly "
                         "ladder: 4096 steps x chunk 256 > 1e6 packets)")
    ap.add_argument("--segment-len", type=int, default=None,
                    help="override the streaming segment length")
    ap.add_argument("--reservoir", type=int, default=None,
                    help="override the latency-reservoir slot count")
    args = ap.parse_args()
    check_flags(ap, args)
    backend = single_backend(ap, args)
    g = dict(TINY if args.tiny else FULL)
    for k, flag in (("steps", args.steps), ("segment_len", args.segment_len),
                    ("reservoir", args.reservoir)):
        if flag is not None:
            g[k] = flag
    if g["steps"] % g["segment_len"]:
        ap.error(f"--steps ({g['steps']}) must be a multiple of "
                 f"--segment-len ({g['segment_len']}) so the timed run "
                 f"has no ragged tail compile")
    rows, summary = bench(g, oracle=args.oracle, backend=backend)
    print_rows(rows)
    if args.json:
        resolved = None
        if backend is not None:
            from repro.backend import as_config
            resolved = as_config(backend).concrete().default
        write_bench_json(args.json, "streaming", rows, summary=summary,
                         backend=resolved)


if __name__ == "__main__":
    main()
