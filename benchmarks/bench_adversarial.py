"""Adversarial & failure benchmark: the DESIGN.md §10 degradation gates.

Runs the ``adversarial`` scenario family (repro.scenarios.adversarial) —
parking-table exhaustion storms, NAT CLOCK-aging churn, a Maglev backend
kill->recover round trip and NF-server failover in both drain/drop modes —
through the vmapped sweep runner, and **asserts graceful degradation**:

  * every per-scenario gate (``bounds_for``) holds: bounded drop rate, a
    clean parked table at end of trace (or a leak attributable to killed
    packets), bounded recovery time after a server fault;
  * the wire-level drop rate is *monotone* in the attack fraction within
    each exhaustion burst series (higher attack fractions are strict
    supersets by construction, so a non-monotone drop rate means the
    parking table failed non-gracefully);
  * the churn points actually exercise the §10 stale-mapping rule
    (``nat_stale_hits`` > 0) — a silent NAT would pass every bound;
  * every point is re-checked engine ≡ host loop (counters + telemetry +
    NF counters) *through its fault event* unless ``--no-verify``.

Exits non-zero when any assertion fails.

    PYTHONPATH=src python benchmarks/bench_adversarial.py
    PYTHONPATH=src python benchmarks/bench_adversarial.py --tiny \
        --json BENCH_adversarial.json

Prints ``name,value,derived`` CSV rows like the other benches; ``--json``
writes the schema-v2 BENCH_adversarial.json artifact whose ``degradation``
block benchmarks/compare.py enforces against the committed baseline.
"""
from __future__ import annotations

import dataclasses
import itertools

try:
    from benchmarks.artifacts import write_bench_json
    from benchmarks.common import (check_flags, make_parser, print_rows,
                                   single_backend)
except ImportError:  # run as a script: benchmarks/ itself is on sys.path
    from artifacts import write_bench_json
    from common import check_flags, make_parser, print_rows, single_backend

import repro.scenarios as S
from repro.scenarios.adversarial import EXHAUST_FRACS


def _exhaust_series(results: dict) -> dict[int, list[tuple[float, float]]]:
    """burst -> [(frac, drop_rate)] in ascending-frac order."""
    series: dict[int, list] = {}
    for name, r in results.items():
        if not name.startswith("exhaust_"):
            continue
        frac = float(r.spec.workload[2])
        burst = int(r.spec.workload[3])
        deg = S.degradation_metrics(r)
        series.setdefault(burst, []).append((frac, deg["drop_rate"]))
    for pts in series.values():
        pts.sort()
    return series


def bench(tiny: bool, skip_oracle: bool = False, backend: str = None):
    specs = S.family("adversarial", tiny=tiny)
    if backend is not None:
        specs = [dataclasses.replace(s, backend=backend) for s in specs]
    result_list = S.run_matrix(specs)
    results = {r.spec.name: r for r in result_list}
    rows = []
    for name, r in results.items():
        rows.extend(S.default_rows(r, "adversarial"))
        for metric, value in S.degradation_metrics(r).items():
            rows.append((f"adversarial/{name}/{metric}", value,
                         f"fault={r.spec.fault.kind}", name))
        if not skip_oracle:
            # raises OracleMismatch on divergence — with the spec's fault
            # mirrored into the loop, so the invariant is proven *through*
            # the fault event, not around it
            S.verify_oracle(r)
            rows.append((
                f"adversarial/{name}/oracle_identical", 1,
                "engine==loop (counters+telemetry+nf) through fault", name))

    degradation = S.degradation_block(result_list)
    failures = [
        f"{name}: {g['metric']} = {g['value']} violates "
        f"{g['metric']} {g['op']} {g['bound']}"
        for name, sc in degradation["scenarios"].items()
        for g in sc["gates"] if not g["ok"]]

    # monotonicity: within one burst series, a higher attack fraction may
    # never *lower* the drop rate (supersets by construction)
    for burst, pts in _exhaust_series(results).items():
        assert [f for f, _ in pts] == sorted(EXHAUST_FRACS), pts
        for (f_lo, d_lo), (f_hi, d_hi) in itertools.pairwise(pts):
            if d_hi < d_lo:
                failures.append(
                    f"exhaust burst={burst}: drop rate not monotone in "
                    f"attack fraction (f={f_lo}: {d_lo} -> f={f_hi}: {d_hi})")

    if failures:
        raise SystemExit("graceful-degradation gates violated:\n  "
                         + "\n  ".join(failures))

    sc = degradation["scenarios"]
    summary = dict(
        degradation_ok=degradation["ok"],
        scenarios=len(results),
        gates=sum(len(s["gates"]) for s in sc.values()),
        exhaust_drop_rate_f00=sc["exhaust_f00_b8"]["metrics"]["drop_rate"],
        exhaust_drop_rate_f75=sc["exhaust_f75_b8"]["metrics"]["drop_rate"],
        failover_drain_leaked=sc["failover_drain"]["metrics"]["occ_final"],
        failover_drop_leaked=sc["failover_drop"]["metrics"]["occ_final"],
        nat_stale_hits=sc["churn_slow"]["metrics"]["nat_stale_hits"],
    )
    matrix = {s.name: s.as_dict() for s in specs}
    return rows, summary, matrix, degradation


def main() -> None:
    # the oracle runs by default here; --oracle is accepted for symmetry
    # with the benches that default it off (benchmarks/common.py)
    ap = make_parser(__doc__)
    args = ap.parse_args()
    check_flags(ap, args)
    backend = single_backend(ap, args)
    rows, summary, matrix, degradation = bench(
        args.tiny, skip_oracle=args.no_verify, backend=backend)
    print_rows(rows)
    if args.json:
        resolved = None
        if backend is not None:
            from repro.backend import as_config
            resolved = as_config(backend).concrete().default
        write_bench_json(args.json, "adversarial", rows, summary=summary,
                         matrix=matrix, degradation=degradation,
                         backend=resolved)


if __name__ == "__main__":
    main()
