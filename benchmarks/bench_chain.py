"""§7 chain benchmark: FW->NAT->LB goodput gain on datacenter traffic.

The paper's last unreproduced headline (§7): with datacenter-characteristic
traffic, the Firewall->NAT->LoadBalancer chain gains 13 % goodput from
payload parking, rising to 28 % when recirculation parks 352 B rows.  This
bench runs the ``chain`` scenario family (repro.scenarios.matrix) — the
Maglev LB's first benchmark exposure — through the vmapped sweep runner and
**asserts the §7 direction**:

  * parking gain on the datacenter workload is strictly positive;
  * recirculation strictly increases it (the 13 % -> 28 % shape);
  * every run is re-checked engine ≡ host-loop (counters + telemetry).

The enterprise mix runs alongside for contrast (the §6 chapters' workload).
Exits non-zero when any assertion fails.

    PYTHONPATH=src python benchmarks/bench_chain.py
    PYTHONPATH=src python benchmarks/bench_chain.py --tiny --json BENCH_chain.json

Prints ``name,value,derived`` CSV rows like the other benches; ``--json``
writes the schema-v2 BENCH_chain.json artifact (benchmarks/artifacts.py)
that CI uploads, gates via benchmarks/compare.py, and figures.py renders
as the §7 chain table.
"""
from __future__ import annotations

import dataclasses

try:
    from benchmarks.artifacts import write_bench_json
    from benchmarks.common import (check_flags, make_parser, print_rows,
                                   single_backend)
except ImportError:  # run as a script: benchmarks/ itself is on sys.path
    from artifacts import write_bench_json
    from common import check_flags, make_parser, print_rows, single_backend

import repro.scenarios as S

PAPER_GAIN_PCT = dict(base=13.0, recirc=28.0)  # §7 reported figures


def bench(tiny: bool, skip_oracle: bool = False, backend: str = None):
    specs = S.family("chain", tiny=tiny)
    if backend is not None:
        specs = [dataclasses.replace(s, backend=backend) for s in specs]
    results = {r.spec.name: r for r in S.run_matrix(specs)}
    rows = []
    gains = {}
    for name, r in results.items():
        gains[name] = r.gain["goodput_gain"]
        rows.extend(S.default_rows(r, "chain"))
        if not skip_oracle:
            S.verify_oracle(r)  # raises OracleMismatch on divergence
            # emitted only when the check actually ran: compare.py gates
            # 'identical' rows bit-for-bit, so a hardcoded 1 under
            # --no-verify would launder an unchecked run as verified
            rows.append((
                f"chain/{name}/oracle_identical", 1,
                "engine==loop (counters+telemetry)", name))

    for wl in ("datacenter", "enterprise"):
        base, rec = gains[f"{wl}_base"], gains[f"{wl}_recirc"]
        rows.append((
            f"chain/{wl}/recirc_uplift", round(rec - base, 4),
            f"gain_base={base:.4f};gain_recirc={rec:.4f};"
            f"paper={PAPER_GAIN_PCT['base']:.0f}%->"
            f"{PAPER_GAIN_PCT['recirc']:.0f}%", None))

    dc_base = gains["datacenter_base"]
    dc_rec = gains["datacenter_recirc"]
    if not dc_base > 0:
        raise SystemExit(
            f"§7 direction violated: FW->NAT->LB parking gain on the "
            f"datacenter workload is not positive ({dc_base:.4f})")
    if not dc_rec > dc_base:
        raise SystemExit(
            f"§7 direction violated: recirculation does not increase the "
            f"chain gain (base={dc_base:.4f}, recirc={dc_rec:.4f})")
    summary = dict(
        datacenter_gain_pct=round(100 * dc_base, 2),
        datacenter_recirc_gain_pct=round(100 * dc_rec, 2),
        paper_gain_pct=PAPER_GAIN_PCT,
        direction_ok=True,
    )
    matrix = {s.name: s.as_dict() for s in specs}
    return rows, summary, matrix


def main() -> None:
    # the oracle runs by default here; --oracle is accepted for symmetry
    # with the benches that default it off (benchmarks/common.py)
    ap = make_parser(__doc__)
    args = ap.parse_args()
    check_flags(ap, args)
    backend = single_backend(ap, args)
    rows, summary, matrix = bench(args.tiny, skip_oracle=args.no_verify,
                                  backend=backend)
    print_rows(rows)
    if args.json:
        resolved = None
        if backend is not None:
            from repro.backend import as_config
            resolved = as_config(backend).concrete().default
        write_bench_json(args.json, "chain", rows, summary=summary,
                         matrix=matrix, backend=resolved)


if __name__ == "__main__":
    main()
