"""Evaluation-matrix driver: run the registered scenario families.

The seed-era ``run.py`` drove the analytic per-figure functions plus two
hand-rolled sweeps — a second, drifting sweep path next to the bench
scripts.  It is now a thin front-end over the scenario subsystem
(``repro.scenarios``, DESIGN.md §8): every family in the registry is
expanded, executed through the vmapped sweep runner, written as a
schema-v2 ``BENCH_<family>.json`` artifact and printed as CSV rows.  This
is the entry point the nightly CI matrix job runs at full scale.

    PYTHONPATH=src python benchmarks/run.py                 # full matrix
    PYTHONPATH=src python benchmarks/run.py --tiny          # smoke scale
    PYTHONPATH=src python benchmarks/run.py --family chain --out-dir out/
    PYTHONPATH=src python benchmarks/run.py --analytic      # + model figures
    PYTHONPATH=src python benchmarks/run.py --tiny --oracle \
        --family adversarial                                # fault oracle

``--analytic`` additionally renders the analytic per-figure rows
(figures.ALL_FIGURES — model curves, no stateful sweep) the seed driver
printed; the curated assertion benches (bench_pipeline / bench_hostmodel /
bench_chain / bench_adversarial) remain the CI gates.  ``--oracle``
re-checks every executed point engine ≡ host loop (counters + telemetry +
NF counters) — with each spec's fault event mirrored into the loop, which
is how CI's fast job proves the invariant *through* fault injection on the
adversarial family.  The adversarial family's artifact additionally
carries the DESIGN.md §10 ``degradation`` block compare.py enforces.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-scale geometry (repro.configs.sweeps.TINY)")
    ap.add_argument("--family", nargs="+", metavar="NAME",
                    help="run only these scenario families (default: all)")
    ap.add_argument("--out-dir", metavar="DIR",
                    help="write one BENCH_<family>.json per family here")
    ap.add_argument("--analytic", action="store_true",
                    help="also render the analytic model figures "
                         "(figures.ALL_FIGURES)")
    ap.add_argument("--oracle", action="store_true",
                    help="assert engine==loop (counters+telemetry+NF "
                         "counters) at every matrix point, faults included")
    args = ap.parse_args()

    import repro.scenarios as S
    try:
        from benchmarks.artifacts import write_bench_json
    except ImportError:  # run as a script
        from artifacts import write_bench_json

    t_start = time.time()
    families = args.family or S.names()
    unknown = [f for f in families if f not in S.names()]
    if unknown:
        ap.error(f"unknown families {unknown}; registered: {S.names()}")

    all_rows = []
    for fam in families:
        t0 = time.time()
        specs = S.family(fam, tiny=args.tiny)
        results = S.run_matrix(specs)
        rows = []
        for r in results:
            rows.extend(S.default_rows(r, fam))
            if args.oracle:
                S.verify_oracle(r)  # raises OracleMismatch on divergence
        degradation = (S.degradation_block(results)
                       if fam == "adversarial" else None)
        oracle = " oracle ok," if args.oracle else ""
        print(f"# {fam}: {len(specs)} scenarios,{oracle} "
              f"{time.time() - t0:.1f}s", file=sys.stderr)
        if args.out_dir:
            os.makedirs(args.out_dir, exist_ok=True)
            write_bench_json(
                os.path.join(args.out_dir, f"BENCH_{fam}.json"), fam, rows,
                matrix={s.name: s.as_dict() for s in specs},
                degradation=degradation)
        all_rows.extend(rows)

    if args.analytic:
        from benchmarks.figures import ALL_FIGURES
        from benchmarks.bench_parking import (core_throughput_rows,
                                              parking_rows)
        for fig in ALL_FIGURES:
            t0 = time.time()
            all_rows.extend(fig())
            print(f"# {fig.__name__} ({time.time() - t0:.1f}s)",
                  file=sys.stderr)
        all_rows.extend(parking_rows())
        all_rows.extend(core_throughput_rows())

    print("name,value,derived")
    for row in all_rows:
        name, value, derived = row[0], row[1], row[2]
        print(f"{name},{value},{str(derived).replace(',', ';')}")
    print(f"# total {time.time() - t_start:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
