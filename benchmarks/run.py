"""Benchmark driver: one function per paper table/figure + the beyond-paper
serving/parking benchmark + the roofline summary.  Prints
``name,value,derived`` CSV (deliverable d)."""
from __future__ import annotations

import sys
import time


def main() -> None:
    t_start = time.time()
    from benchmarks.figures import ALL_FIGURES
    from benchmarks.bench_parking import core_throughput_rows, parking_rows
    from benchmarks import roofline

    rows = []
    for fig in ALL_FIGURES:
        t0 = time.time()
        out = fig(); dt = time.time() - t0
        rows.extend(out)
        print(f"# {fig.__name__} ({dt:.1f}s)", file=sys.stderr)
    rows.extend(parking_rows())
    rows.extend(core_throughput_rows())
    rows.extend(roofline.bench_rows())

    print("name,value,derived")
    for name, value, derived in rows:
        d = str(derived).replace(",", ";")
        print(f"{name},{value},{d}")
    print(f"# total {time.time() - t_start:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
