"""Roofline analysis from the dry-run's compiled artifacts (deliverable g).

Per (arch x shape x mesh) cell, using the exact (probe-extrapolated) HLO
accounting from launch/dryrun.py — all quantities are PER DEVICE (the
post-SPMD partitioned module):

    compute term    = HLO_FLOPs_dev / peak_FLOPs        (197 TFLOP/s bf16)
    memory term     = HLO_bytes_dev / HBM_bw            (819 GB/s)
    collective term = collective_wire_bytes_dev / ICI   (50 GB/s/link)

The dominant term is the bottleneck; roofline fraction = compute_term /
max(all terms) (the MFU upper bound if compute overlapped perfectly with
everything else).  MODEL_FLOPS uses the assignment's convention: 6·N·D for
training (N = active params, D = tokens), 2·N·D for prefill, 2·N·B per
decode step.  The MODEL/HLO ratio exposes remat and redundant compute.

An analytic per-device memory fit (params/optimizer/cache/residuals) is
reported alongside XLA's memory_analysis, whose CPU-backend numbers are
aggregate, not per-device (EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import glob
import json
import os

from repro import configs
from repro.configs.shapes import SHAPES

PEAK_FLOPS = 197e12     # TPU v5e bf16
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s per link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "dryrun_results")


def model_flops_per_device(arch: str, shape_name: str, devices: int) -> float:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        total = 6.0 * n * shape.seq_len * shape.global_batch
    elif shape.kind == "prefill":
        total = 2.0 * n * shape.seq_len * shape.global_batch
    else:  # decode: one token per request
        total = 2.0 * n * shape.global_batch
    return total / devices


def analytic_memory_gb(arch: str, shape_name: str, devices: int,
                       mesh_kind: str) -> dict:
    """First-principles per-device HBM budget (bf16 params, f32 adam)."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    n = cfg.param_count()
    # params replicated across pods, sharded across one pod's 256 chips
    shard = min(devices, 256)
    out = {}
    if shape.kind == "train":
        out["params+opt+grads"] = n * (2 + 4 + 4 + 4) / shard / 1e9
        # saved residuals: one (B_dev, S/model, D) bf16 per layer (SP on)
        bdev = shape.global_batch / (devices / 16)  # data(+pod) shards
        out["residuals"] = (cfg.num_layers * bdev * shape.seq_len / 16
                            * cfg.d_model * 2) / 1e9
    else:
        out["params"] = n * 2 / shard / 1e9
        if cfg.family == "ssm":
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            state = cfg.num_layers * shape.global_batch * (
                (d_in // s.head_dim) * s.d_state * s.head_dim * 4)
            out["state"] = state / devices / 1e9
        elif cfg.mla is not None:
            per_tok = cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim
            out["cache"] = (cfg.num_layers * shape.global_batch
                            * min(shape.seq_len, 1 << 30) * per_tok * 2
                            / devices / 1e9)
        else:
            eff_len = shape.seq_len
            if cfg.window:
                eff_len = min(eff_len, cfg.window)
            if cfg.family == "hybrid":
                eff_len = min(eff_len, cfg.hybrid.local_window)
            out["cache"] = (cfg.num_layers * shape.global_batch * eff_len
                            * 2 * cfg.num_kv_heads * cfg.head_dim * 2
                            / devices / 1e9)
    out["total"] = sum(out.values())
    return out


SUGGESTIONS = {
    "compute": ("MXU-bound: raise arithmetic efficiency — larger per-device "
                "batch/microbatching, drop remat recompute (policy=dots), "
                "or quantize the FFN path."),
    "memory": ("HBM-bound: fuse attention (Pallas flash kernel keeps scores "
               "in VMEM), widen per-step tiles, or shrink decode batch "
               "padding; for decode, page the KV pool so only live pages "
               "stream."),
    "collective": ("ICI-bound: reduce per-layer all-gathers (FSDP prefetch/"
                   "persistent gathered weights), quantize gradients (int8 "
                   "error-feedback), or reshard so contractions psum less "
                   "often."),
}


def analyze_cell(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    c = rec["cost"]
    devices = rec["devices"]
    terms = {
        "compute": c["flops"] / PEAK_FLOPS,
        "memory": c["bytes_accessed"] / HBM_BW,
        "collective": c["coll_total_bytes"] / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    tmax = max(terms.values())
    mf = model_flops_per_device(rec["arch"], rec["shape"], devices)
    return {
        "cell": rec["cell"],
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": terms["compute"],
        "memory_s": terms["memory"],
        "collective_s": terms["collective"],
        "dominant": dominant,
        "roofline_fraction": terms["compute"] / tmax if tmax else 0.0,
        "model_flops_dev": mf,
        "hlo_flops_dev": c["flops"],
        "model_over_hlo": mf / c["flops"] if c["flops"] else 0.0,
        "analytic_mem_gb": analytic_memory_gb(
            rec["arch"], rec["shape"], devices, rec["mesh"])["total"],
        "fits_16gb": analytic_memory_gb(
            rec["arch"], rec["shape"], devices, rec["mesh"])["total"] < 16.0,
        "suggestion": SUGGESTIONS[dominant],
    }


def load_all(results_dir: str = RESULTS_DIR, mesh: str | None = None,
             tag_filter: str = "") -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        rec = json.load(open(f))
        parts = rec["cell"].split("__")
        has_tag = len(parts) > 3
        if tag_filter == "" and has_tag:
            continue
        if tag_filter and (not has_tag or parts[3] != tag_filter):
            continue
        if mesh and rec.get("mesh") != mesh and parts[2] != mesh:
            continue
        a = analyze_cell(rec)
        if a:
            a["skipped"] = False
            out.append(a)
        elif rec.get("status") == "skipped":
            out.append({"cell": rec["cell"], "skipped": True,
                        "reason": rec["reason"]})
    return out


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| cell | compute s | memory s | collective s | dominant | "
           "roofline frac | model/HLO | mem GB/dev |\n"
           "|---|---|---|---|---|---|---|---|\n")
    body = []
    for r in rows:
        if r.get("skipped"):
            body.append(f"| {r['cell']} | — | — | — | SKIPPED | — | — | — |")
            continue
        body.append(
            f"| {r['cell']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['roofline_fraction']:.2f} | {r['model_over_hlo']:.2f} | "
            f"{r['analytic_mem_gb']:.1f} |")
    return hdr + "\n".join(body) + "\n"


def bench_rows() -> list[tuple]:
    """Roofline summary rows (``name, value, derived`` tuples).

    Formerly glued into the seed-era run.py driver; now emitted as a
    standalone BENCH_roofline.json artifact (``python benchmarks/
    roofline.py --json BENCH_roofline.json``) so the artifact/figures/
    compare tooling is the single consumption path for every benchmark.
    """
    rows = []
    singles = [r for r in load_all(mesh="single") if not r.get("skipped")]
    if not singles:
        return [("roofline/cells_analyzed", 0, "run launch/dryrun first")]
    rows.append(("roofline/cells_analyzed", len(singles), "single-pod"))
    worst = min(singles, key=lambda r: r["roofline_fraction"])
    coll = max(singles, key=lambda r: r["collective_s"])
    rows.append(("roofline/worst_fraction_cell", worst["cell"],
                 f"frac={worst['roofline_fraction']:.2f}"))
    rows.append(("roofline/most_collective_bound", coll["cell"],
                 f"coll_s={coll['collective_s']:.3e}"))
    for r in singles:
        rows.append((f"roofline/{r['cell']}/fraction",
                     round(r["roofline_fraction"], 3),
                     f"dom={r['dominant']},model/hlo="
                     f"{r['model_over_hlo']:.2f}"))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("mesh", nargs="?", default=None,
                    help="restrict to one mesh kind (e.g. 'single')")
    ap.add_argument("--json", metavar="PATH",
                    help="also write the BENCH json artifact here "
                         "(benchmarks/artifacts.py schema)")
    args = ap.parse_args()
    print(markdown_table(load_all(mesh=args.mesh)))
    if args.json:
        try:
            from benchmarks.artifacts import write_bench_json
        except ImportError:  # run as a script
            from artifacts import write_bench_json
        write_bench_json(args.json, "roofline", bench_rows())
