"""Beyond-paper benchmark: PayloadPark applied to LM serving.

Quantifies the paper's goodput argument in the serving domain: per decoded
token, what crosses the pod/data network under
  (a) header-only routing with parked KV pages (our design),
  (b) full request-state migration (ship the KV/state payload), and
  (c) layer-activation forwarding (pipeline the token through remote shards).

The ratio (b)/(a) is the serving analogue of the paper's goodput gain; it
grows with context length exactly as the paper's gain grows with packet size.
Also times the core Split/Merge state machine on CPU (packets/sec) so the
dataplane implementation has a measured number.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.park import ParkConfig, init_state, merge, split
from repro.core.packet import make_udp_batch
from repro.serving.engine import (HEADER_BYTES_PER_PAGE, HEADER_FIXED_BYTES,
                                  parked_payload_bytes)

PAGE_TOKENS = 128


def header_bytes(position: int) -> int:
    pages = (position + PAGE_TOKENS - 1) // PAGE_TOKENS
    return HEADER_FIXED_BYTES + HEADER_BYTES_PER_PAGE * pages


def parking_rows():
    rows = []
    for arch in ("gemma-7b", "qwen3-32b", "deepseek-v2-236b", "mamba2-1.3b"):
        cfg = configs.get(arch)
        for pos in (4096, 32768):
            hdr = header_bytes(pos)
            payload = parked_payload_bytes(cfg, pos)
            act = cfg.d_model * 2  # one token's activation per hop
            rows.append((f"parking/{arch}@{pos}/header_bytes", hdr,
                         f"payload_migration={payload:.3e},"
                         f"activation_fwd={act},"
                         f"goodput_gain_vs_migration={payload / hdr:.1f}x"))
    return rows


def core_throughput_rows():
    cfg = ParkConfig(capacity=8192, max_exp=2, pmax=512)
    st = init_state(cfg)
    pkts = make_udp_batch(jax.random.key(0), 4096, 384, pmax=512)
    # warm up + compile
    st2, sent = split(cfg, st, pkts)
    st3, merged = merge(cfg, st2, sent)
    jax.block_until_ready(merged.payload)
    t0 = time.perf_counter()
    n = 3
    for _ in range(n):
        st2, sent = split(cfg, st, pkts)
        st3, merged = merge(cfg, st2, sent)
        jax.block_until_ready(merged.payload)
    dt = (time.perf_counter() - t0) / n
    pps = pkts.batch_size / dt
    us_per_pkt = dt / pkts.batch_size * 1e6
    return [
        ("core/split_merge_us_per_pkt_cpu", round(us_per_pkt, 3),
         f"pps={pps:.0f} (1-core CPU interpret; Tofino does this "
         f"at line rate in hardware)"),
    ]
