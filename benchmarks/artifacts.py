"""BENCH_*.json artifact schema shared by the benchmark writers and the
figures consumer.

Every benchmark that contributes to the per-commit trajectory writes one
``BENCH_<name>.json`` via ``write_bench_json`` (CI uploads them as
workflow artifacts), and ``benchmarks/figures.py`` re-renders the rows
from those files via ``load_bench_json`` — consuming the artifact instead
of re-running the simulation, and failing loudly on a missing or
malformed file.

Schema (version 1):

    {
      "schema": 1,
      "bench": "<benchmark name>",
      "rows": [{"name": str, "value": int|float, "derived": str}, ...],
      "summary": {...}          # benchmark-specific headline numbers
    }
"""
from __future__ import annotations

import json
import os

SCHEMA_VERSION = 1


class BenchArtifactError(RuntimeError):
    """A BENCH_*.json file is missing or does not match the schema."""


def rows_to_json(rows) -> list[dict]:
    """Convert the benches' ``(name, value, derived)`` tuples."""
    return [{"name": n, "value": v, "derived": str(d)} for n, v, d in rows]


def write_bench_json(path: str, bench: str, rows, summary: dict | None = None,
                     ) -> dict:
    """Write one benchmark artifact; returns the payload written."""
    payload = {
        "schema": SCHEMA_VERSION,
        "bench": bench,
        "rows": rows_to_json(rows),
        "summary": summary or {},
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return payload


def load_bench_json(path: str) -> dict:
    """Load + validate one artifact; raises BenchArtifactError on any
    missing file or schema violation (never returns a partial payload)."""
    if not os.path.exists(path):
        raise BenchArtifactError(f"missing benchmark artifact: {path}")
    try:
        with open(path) as f:
            payload = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise BenchArtifactError(f"malformed JSON in {path}: {e}") from e
    if not isinstance(payload, dict):
        raise BenchArtifactError(f"{path}: top level must be an object")
    if payload.get("schema") != SCHEMA_VERSION:
        raise BenchArtifactError(
            f"{path}: schema {payload.get('schema')!r} != {SCHEMA_VERSION}")
    if not isinstance(payload.get("bench"), str) or not payload["bench"]:
        raise BenchArtifactError(f"{path}: 'bench' must be a non-empty string")
    rows = payload.get("rows")
    if not isinstance(rows, list):
        raise BenchArtifactError(f"{path}: 'rows' must be a list")
    for i, row in enumerate(rows):
        if (not isinstance(row, dict) or "name" not in row
                or "value" not in row):
            raise BenchArtifactError(
                f"{path}: rows[{i}] must be an object with name/value")
    if not isinstance(payload.get("summary", {}), dict):
        raise BenchArtifactError(f"{path}: 'summary' must be an object")
    return payload
