"""BENCH_*.json artifact schema shared by the benchmark writers, the
figures consumer and the CI regression gate.

Every benchmark that contributes to the per-commit trajectory writes one
``BENCH_<name>.json`` via ``write_bench_json`` (CI uploads them as
workflow artifacts), ``benchmarks/figures.py`` re-renders the rows from
those files via ``load_bench_json``, and ``benchmarks/compare.py`` diffs
them against the committed baselines under ``benchmarks/baselines/`` —
failing CI when a metric drifts past its tolerance.

Schema (version 2):

    {
      "schema": 2,
      "bench": "<benchmark name>",
      "backend": str,                 # optional: the dataplane backend the
                                      #   whole run used (repro.backend name,
                                      #   e.g. "ref" / "pallas_interpret");
                                      #   omitted for multi-backend sweeps
                                      #   (the per-scenario matrix block then
                                      #   carries it per point)
      "rows": [{"name": str,          # unique metric path, e.g.
                                      #   "chain/datacenter_base/goodput_gain"
                "value": int|float|str,
                "derived": str,       # auxiliary context, never gated on
                "scenario": str},     # optional: the ScenarioSpec.name this
                                      #   row was measured on (schema v2)
               ...],
      "summary": {...},               # benchmark-specific headline numbers
      "matrix": {                     # optional (schema v2): the declarative
        "<scenario name>": {...}      #   ScenarioSpec fields behind each
      },                              #   scenario, for artifact provenance
      "degradation": {                # optional (schema v2): graceful-
        "ok": bool,                   #   degradation gate verdicts from the
        "scenarios": {                #   adversarial families (DESIGN.md
          "<scenario name>": {        #   §10) — compare.py FAILS an artifact
            "metrics": {...},         #   carrying any false gate, and
            "gates": [{"metric": str, #   requires every baseline gate to
                       "op": str,     #   still exist in the candidate
                       "bound": num|str,
                       "value": num,
                       "ok": bool}]
          }
        }
      }
    }

v1 -> v2: rows gained the optional ``scenario`` field and the top level
gained the optional ``matrix`` block, both written by benches that run
through ``repro.scenarios`` (the vmapped sweep runner); the optional
top-level ``backend`` provenance field was added with the dataplane-backend
layer (compare.py keys its per-backend baseline matching on it), the
optional ``degradation`` block with the adversarial families.
``load_bench_json`` accepts only the current version; regenerate baselines
when bumping.
"""
from __future__ import annotations

import json
import os

SCHEMA_VERSION = 2


class BenchArtifactError(RuntimeError):
    """A BENCH_*.json file is missing or does not match the schema."""


def rows_to_json(rows) -> list[dict]:
    """Convert the benches' row tuples: ``(name, value, derived)`` or
    ``(name, value, derived, scenario)`` (schema v2)."""
    out = []
    for row in rows:
        name, value, derived = row[0], row[1], row[2]
        d = {"name": name, "value": value, "derived": str(derived)}
        if len(row) > 3 and row[3] is not None:
            d["scenario"] = str(row[3])
        out.append(d)
    return out


def write_bench_json(path: str, bench: str, rows, summary: dict | None = None,
                     matrix: dict | None = None,
                     backend: str | None = None,
                     degradation: dict | None = None) -> dict:
    """Write one benchmark artifact; returns the payload written.

    ``matrix`` maps scenario names to their declarative spec dicts
    (``ScenarioSpec.as_dict()``) for provenance; omitted when the bench
    does not run through the scenario subsystem.  ``backend`` records the
    dataplane backend a single-backend run used (omit it for multi-backend
    sweeps — each scenario's matrix entry carries its own).
    ``degradation`` is the graceful-degradation block the adversarial
    families emit (``repro.scenarios.degradation_block``).
    """
    payload = {
        "schema": SCHEMA_VERSION,
        "bench": bench,
        "rows": rows_to_json(rows),
        "summary": summary or {},
    }
    if matrix:
        payload["matrix"] = matrix
    if backend is not None:
        payload["backend"] = backend
    if degradation is not None:
        payload["degradation"] = degradation
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return payload


def load_bench_json(path: str) -> dict:
    """Load + validate one artifact; raises BenchArtifactError on any
    missing file or schema violation (never returns a partial payload)."""
    if not os.path.exists(path):
        raise BenchArtifactError(f"missing benchmark artifact: {path}")
    try:
        with open(path) as f:
            payload = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise BenchArtifactError(f"malformed JSON in {path}: {e}") from e
    if not isinstance(payload, dict):
        raise BenchArtifactError(f"{path}: top level must be an object")
    if payload.get("schema") != SCHEMA_VERSION:
        raise BenchArtifactError(
            f"{path}: schema {payload.get('schema')!r} != {SCHEMA_VERSION}")
    if not isinstance(payload.get("bench"), str) or not payload["bench"]:
        raise BenchArtifactError(f"{path}: 'bench' must be a non-empty string")
    if "backend" in payload and (
            not isinstance(payload["backend"], str) or not payload["backend"]):
        raise BenchArtifactError(
            f"{path}: 'backend' must be a non-empty string when present")
    rows = payload.get("rows")
    if not isinstance(rows, list):
        raise BenchArtifactError(f"{path}: 'rows' must be a list")
    seen = set()
    for i, row in enumerate(rows):
        if (not isinstance(row, dict) or "name" not in row
                or "value" not in row):
            raise BenchArtifactError(
                f"{path}: rows[{i}] must be an object with name/value")
        if "scenario" in row and not isinstance(row["scenario"], str):
            raise BenchArtifactError(
                f"{path}: rows[{i}].scenario must be a string")
        if row["name"] in seen:
            raise BenchArtifactError(
                f"{path}: duplicate row name {row['name']!r}")
        seen.add(row["name"])
    if not isinstance(payload.get("summary", {}), dict):
        raise BenchArtifactError(f"{path}: 'summary' must be an object")
    if not isinstance(payload.get("matrix", {}), dict):
        raise BenchArtifactError(f"{path}: 'matrix' must be an object")
    if "degradation" in payload:
        _validate_degradation(path, payload["degradation"])
    return payload


def _validate_degradation(path: str, deg) -> None:
    if not isinstance(deg, dict) or not isinstance(deg.get("ok"), bool) \
            or not isinstance(deg.get("scenarios"), dict):
        raise BenchArtifactError(
            f"{path}: 'degradation' must be an object with a bool 'ok' "
            f"and a 'scenarios' object")
    for name, sc in deg["scenarios"].items():
        if (not isinstance(sc, dict) or not isinstance(sc.get("metrics"), dict)
                or not isinstance(sc.get("gates"), list)):
            raise BenchArtifactError(
                f"{path}: degradation.scenarios[{name!r}] must carry "
                f"'metrics' (object) and 'gates' (list)")
        for i, g in enumerate(sc["gates"]):
            if (not isinstance(g, dict) or "metric" not in g or "op" not in g
                    or "bound" not in g or "value" not in g
                    or not isinstance(g.get("ok"), bool)):
                raise BenchArtifactError(
                    f"{path}: degradation gate {name}[{i}] must carry "
                    f"metric/op/bound/value and a bool 'ok'")


def row_map(payload: dict) -> dict[str, dict]:
    """Rows keyed by name (names are unique per load_bench_json)."""
    return {r["name"]: r for r in payload["rows"]}
