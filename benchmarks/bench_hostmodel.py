"""NF-server host-model benchmark: PCIe bus-load reduction and
server-bound throughput from measured per-link telemetry.

Reproduces the abstract's last headline claim — "PayloadPark reduces PCIe
bus load by 2-58% on the NF server" — on the *stateful* engine rather
than the analytic model (``figures.fig9_pcie_utilization``): every run
streams a workload through the scanned engine, takes the per-link byte/
packet telemetry (DESIGN.md §7), and feeds it to ``repro.hostmodel``'s
PCIe/DMA accounting (TLP + descriptor overheads included).

Two sweeps:

  * **size sweep** — fixed 256..1492 B packets plus the enterprise
    workload on a MacSwap chain (no chain drops, so the reduction is a
    pure function of the parked share).  Asserts every reduction lands in
    the paper's 2-58% band AND is monotone in the workload's
    splittable-payload share; each run is re-checked bit-identical
    against the host-loop oracle (telemetry included).
  * **server sweep** — 1..8 NF servers (one per-port pipe each, §6.3.2)
    on enterprise traffic through a dropping FW->NAT chain, with each
    server's lookup-table slice taken from the §6.2.3 placement model
    (``hostmodel.per_server_capacity``).  Reports aggregate + per-server
    PCIe reduction and the cycle-budget server pps bound.

    PYTHONPATH=src python benchmarks/bench_hostmodel.py
    PYTHONPATH=src python benchmarks/bench_hostmodel.py --tiny --json BENCH_hostmodel.json

Prints ``name,value,derived`` CSV rows like the other benches; ``--json``
additionally writes the BENCH_hostmodel.json artifact (benchmarks/
artifacts.py schema) that CI uploads and ``figures.py`` consumes.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

try:
    from benchmarks.artifacts import write_bench_json
except ImportError:  # run as a script: benchmarks/ itself is on sys.path
    from artifacts import write_bench_json

from repro.core.packet import to_time_major
from repro.core.park import ParkConfig
from repro.hostmodel import HostModel, server_report, per_server_capacity
from repro.nf.chain import Chain
from repro.nf.firewall import Firewall
from repro.nf.macswap import MacSwap
from repro.nf.nat import Nat
from repro.switchsim import engine as E
from repro.switchsim.simulate import simulate_loop
from repro.traffic.generator import enterprise, fixed, steer_pipes

BAND_PCT = (2.0, 58.0)  # the paper's PCIe-load reduction band (abstract)


def _check_band(name: str, red_pct: float) -> None:
    lo, hi = BAND_PCT
    if not lo <= red_pct <= hi:
        raise SystemExit(
            f"PCIe reduction for {name} outside the paper's band "
            f"[{lo}, {hi}]%: {red_pct:.2f}%")


def _verify_oracle(cfg, chain, pkts, res, window, chunk, label):
    """Engine ≡ host-loop, telemetry included (the acceptance re-check)."""
    loop = simulate_loop(cfg, chain, pkts, window=window, chunk=chunk)
    if not (res.telemetry == loop.telemetry
            and res.counters == loop.counters):
        raise SystemExit(
            f"engine telemetry diverged from loop oracle @{label}:\n"
            f"  engine: {res.telemetry}\n  loop:   {loop.telemetry}")


def bench_sizes(sizes, n_pkts, chunk, window, capacity, pmax, host):
    """Fixed-size + enterprise sweep on one pipe; band + monotonicity."""
    chain = Chain((MacSwap(),))
    cfg = ParkConfig(capacity=capacity, max_exp=2, pmax=pmax)
    rows = []
    runs = []  # (splittable share, reduction %, workload name)
    workloads = [fixed(s) for s in sizes] + [enterprise()]
    for i, wl in enumerate(workloads):
        pkts = wl.make_batch(jax.random.key(i), n_pkts, pmax=pmax)
        res = E.run_engine(cfg, chain, to_time_major(pkts, chunk),
                           window=window)
        _verify_oracle(cfg, chain, pkts, res, window, chunk, wl.name)
        rep = server_report(host, res.telemetry, chain.cycle_costs())
        red_pct = 100.0 * rep["pcie_reduction"]
        share = wl.splittable_share(cfg.min_park_len, cfg.park_bytes)
        _check_band(wl.name, red_pct)
        runs.append((share, red_pct, wl.name))
        rows.append((
            f"hostmodel/{wl.name}/pcie_reduction_pct", round(red_pct, 2),
            f"paper=2..58%;splittable_share={share:.3f};"
            f"bus_parked={rep['parked_bus_bytes']};"
            f"bus_base={rep['baseline_bus_bytes']};"
            f"server_pps_gain={rep['server_pps_gain']:.4f};"
            f"bottleneck={rep['bottleneck_parked']};"
            f"oracle=identical"))
        rows.append((
            f"hostmodel/{wl.name}/server_pps_parked",
            round(rep["server_pps_parked"]),
            f"baseline={rep['server_pps_baseline']:.0f};"
            f"bottleneck_base={rep['bottleneck_baseline']}"))
    # The reduction must grow with the share of bytes Split can park.
    runs.sort(key=lambda r: r[0])
    for (s0, r0, n0), (s1, r1, n1) in zip(runs, runs[1:]):
        if r1 < r0 - 1e-9:
            raise SystemExit(
                f"PCIe reduction not monotone in splittable share: "
                f"{n0} (share {s0:.3f}) -> {r0:.2f}% but "
                f"{n1} (share {s1:.3f}) -> {r1:.2f}%")
    return rows, {r[2]: round(r[1], 2) for r in runs}


def bench_servers(server_counts, n_pkts, chunk, window, pmax, host,
                  mem_frac=0.40):
    """1..8 servers, one pipe each (§6.3.2), enterprise + FW->NAT."""
    wl = enterprise()
    pkts = wl.make_batch(jax.random.key(99), n_pkts, pmax=pmax)
    rules = tuple(int(ip) for ip in
                  np.unique(np.asarray(pkts.src_ip))[:20].tolist())
    chain = Chain((Firewall(rules=rules), Nat()))
    rows = []
    summary = {}
    for n in server_counts:
        capacity = per_server_capacity(mem_frac, ParkConfig(pmax=pmax), n)
        cfg = ParkConfig(capacity=capacity, max_exp=2, pmax=pmax)
        shards, stats = steer_pipes(pkts, n, chunk=chunk)
        traces = jax.tree.map(
            lambda a: a.reshape(
                (n, a.shape[1] // chunk, chunk) + a.shape[2:]), shards)
        res = E.run_pipes(cfg, chain, traces, window=window)
        rep = server_report(host, res.telemetry, chain.cycle_costs())
        red_pct = 100.0 * rep["pcie_reduction"]
        _check_band(f"servers{n}", red_pct)
        per_srv = [100.0 * server_report(host, t, chain.cycle_costs())
                   ["pcie_reduction"]
                   for t in res.per_pipe_telemetry]
        rows.append((
            f"hostmodel/servers{n}/pcie_reduction_pct", round(red_pct, 2),
            f"per_server_min={min(per_srv):.2f};"
            f"per_server_max={max(per_srv):.2f};"
            f"table_slice={capacity};overflow={stats['overflow']};"
            f"server_pps_parked={rep['server_pps_parked']:.0f};"
            f"bottleneck={rep['bottleneck_parked']}"))
        summary[f"servers{n}"] = round(red_pct, 2)
    return rows, summary


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--packets", type=int, default=4096)
    ap.add_argument("--chunk", type=int, default=256)
    ap.add_argument("--window", type=int, default=2)
    ap.add_argument("--capacity", type=int, default=4096)
    ap.add_argument("--pmax", type=int, default=2048)
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=[256, 384, 512, 1024, 1492])
    ap.add_argument("--servers", type=int, nargs="+",
                    default=[1, 2, 4, 8])
    ap.add_argument("--pcie-gen", type=int, default=3)
    ap.add_argument("--pcie-lanes", type=int, default=8)
    ap.add_argument("--json", metavar="PATH",
                    help="also write the BENCH json artifact here")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: 512 packets, chunk 64, 2 sizes, "
                         "2 server counts")
    args = ap.parse_args()
    if args.tiny:
        args.packets, args.chunk, args.capacity = 512, 64, 512
        args.pmax = 2048
        args.sizes = [256, 1492]
        args.servers = [1, 2]
    if args.packets % args.chunk:
        ap.error(f"--packets ({args.packets}) must be a multiple of "
                 f"--chunk ({args.chunk})")
    from repro.hostmodel import PcieLink
    host = HostModel(link=PcieLink(gen=args.pcie_gen, lanes=args.pcie_lanes))

    rows, size_summary = bench_sizes(
        args.sizes, args.packets, args.chunk, args.window, args.capacity,
        args.pmax, host)
    srv_rows, srv_summary = bench_servers(
        args.servers, args.packets, args.chunk, args.window, args.pmax, host)
    rows += srv_rows

    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value},{str(derived).replace(',', ';')}")
    if args.json:
        write_bench_json(args.json, "hostmodel", rows, summary=dict(
            band_pct=list(BAND_PCT),
            pcie_reduction_pct={**size_summary, **srv_summary},
            monotone_in_splittable_share=True,
            pcie=dict(gen=args.pcie_gen, lanes=args.pcie_lanes),
        ))


if __name__ == "__main__":
    main()
