"""NF-server host-model benchmark: PCIe bus-load reduction and
server-bound throughput from measured per-link telemetry.

Reproduces the abstract's last headline claim — "PayloadPark reduces PCIe
bus load by 2-58% on the NF server" — on the *stateful* engine rather
than the analytic model (``figures.fig9_pcie_utilization``): every run
streams a workload through the scanned engine, takes the per-link byte/
packet telemetry (DESIGN.md §7), and feeds it to ``repro.hostmodel``'s
PCIe/DMA accounting (TLP + descriptor overheads included).

Both sweeps are scenario families (repro.scenarios.matrix) executed by
the vmapped sweep runner — the size sweep's fixed-size and enterprise
points share one compiled engine (DESIGN.md §8):

  * **size sweep** (``hostmodel_sizes``) — fixed 256..1492 B packets plus
    the enterprise workload on a MacSwap chain (no chain drops, so the
    reduction is a pure function of the parked share).  Asserts every
    reduction lands in the paper's 2-58% band AND is monotone in the
    workload's splittable-payload share; each run is re-checked against
    the host-loop oracle (counters + telemetry).
  * **server sweep** (``hostmodel_servers``) — 1..8 NF servers (one
    per-port pipe each, §6.3.2) on enterprise traffic through a dropping
    FW->NAT chain, with each server's lookup-table slice taken from the
    §6.2.3 placement model (``hostmodel.per_server_capacity``).  Reports
    aggregate + per-server PCIe reduction and the cycle-budget server pps
    bound.

    PYTHONPATH=src python benchmarks/bench_hostmodel.py
    PYTHONPATH=src python benchmarks/bench_hostmodel.py --tiny --json BENCH_hostmodel.json

Prints ``name,value,derived`` CSV rows like the other benches; ``--json``
additionally writes the schema-v2 BENCH_hostmodel.json artifact
(benchmarks/artifacts.py) that CI uploads and gates via compare.py.
"""
from __future__ import annotations

import dataclasses

try:
    from benchmarks.artifacts import write_bench_json
    from benchmarks.common import (check_flags, make_parser, print_rows,
                                   single_backend)
except ImportError:  # run as a script: benchmarks/ itself is on sys.path
    from artifacts import write_bench_json
    from common import check_flags, make_parser, print_rows, single_backend

import repro.scenarios as S
from repro.hostmodel import HostModel, server_report

BAND_PCT = (2.0, 58.0)  # the paper's PCIe-load reduction band (abstract)


def _check_band(name: str, red_pct: float) -> None:
    lo, hi = BAND_PCT
    if not lo <= red_pct <= hi:
        raise SystemExit(
            f"PCIe reduction for {name} outside the paper's band "
            f"[{lo}, {hi}]%: {red_pct:.2f}%")


def _family(name, tiny, backend):
    specs = S.family(name, tiny=tiny)
    if backend is not None:
        specs = [dataclasses.replace(s, backend=backend) for s in specs]
    return specs


def bench_sizes(tiny, host, skip_oracle=False, backend=None):
    """Fixed-size + enterprise sweep on one pipe; band + monotonicity."""
    specs = _family("hostmodel_sizes", tiny, backend)
    results = S.run_matrix(specs)
    rows = []
    runs = []  # (splittable share, reduction %, workload name)
    for spec, res in zip(specs, results):
        if not skip_oracle:
            S.verify_oracle(res)  # engine == loop, counters + telemetry
        rep = server_report(host, res.telemetry, res.nf_cycles)
        red_pct = 100.0 * rep["pcie_reduction"]
        cfg = spec.park_config()
        wl = S.resolve_workload(spec.workload)
        share = wl.splittable_share(cfg.min_park_len, cfg.park_bytes)
        _check_band(spec.name, red_pct)
        runs.append((share, red_pct, spec.name))
        # the oracle token appears only when the check actually ran — a
        # hardcoded one under --no-verify would launder an unchecked run
        oracle = "" if skip_oracle else ";oracle=identical"
        rows.append((
            f"hostmodel/{spec.name}/pcie_reduction_pct", round(red_pct, 2),
            f"paper=2..58%;splittable_share={share:.3f};"
            f"bus_parked={rep['parked_bus_bytes']};"
            f"bus_base={rep['baseline_bus_bytes']};"
            f"server_pps_gain={rep['server_pps_gain']:.4f};"
            f"bottleneck={rep['bottleneck_parked']}" + oracle, spec.name))
        rows.append((
            f"hostmodel/{spec.name}/server_pps_parked",
            round(rep["server_pps_parked"]),
            f"baseline={rep['server_pps_baseline']:.0f};"
            f"bottleneck_base={rep['bottleneck_baseline']}", spec.name))
    # The reduction must grow with the share of bytes Split can park.
    runs.sort(key=lambda r: r[0])
    for (s0, r0, n0), (s1, r1, n1) in zip(runs, runs[1:]):
        if r1 < r0 - 1e-9:
            raise SystemExit(
                f"PCIe reduction not monotone in splittable share: "
                f"{n0} (share {s0:.3f}) -> {r0:.2f}% but "
                f"{n1} (share {s1:.3f}) -> {r1:.2f}%")
    matrix = {s.name: s.as_dict() for s in specs}
    return rows, {r[2]: round(r[1], 2) for r in runs}, matrix


def bench_servers(tiny, host, backend=None):
    """1..8 servers, one pipe each (§6.3.2), enterprise + FW->NAT."""
    specs = _family("hostmodel_servers", tiny, backend)
    results = S.run_matrix(specs)
    rows = []
    summary = {}
    for spec, res in zip(specs, results):
        n = spec.pipes
        rep = server_report(host, res.telemetry, res.nf_cycles)
        red_pct = 100.0 * rep["pcie_reduction"]
        _check_band(spec.name, red_pct)
        per_srv = [100.0 * server_report(host, t, res.nf_cycles)
                   ["pcie_reduction"]
                   for t in res.per_pipe_telemetry]
        rows.append((
            f"hostmodel/servers{n}/pcie_reduction_pct", round(red_pct, 2),
            f"per_server_min={min(per_srv):.2f};"
            f"per_server_max={max(per_srv):.2f};"
            f"table_slice={spec.capacity};"
            f"overflow={res.steer_stats['overflow']};"
            f"server_pps_parked={rep['server_pps_parked']:.0f};"
            f"bottleneck={rep['bottleneck_parked']}", spec.name))
        summary[f"servers{n}"] = round(red_pct, 2)
    matrix = {s.name: s.as_dict() for s in specs}
    return rows, summary, matrix


def main() -> None:
    # the size sweep's oracle runs by default; --oracle is accepted for
    # symmetry with the benches that default it off (benchmarks/common.py)
    ap = make_parser(__doc__)
    ap.add_argument("--pcie-gen", type=int, default=3)
    ap.add_argument("--pcie-lanes", type=int, default=8)
    args = ap.parse_args()
    check_flags(ap, args)
    backend = single_backend(ap, args)
    from repro.hostmodel import PcieLink
    host = HostModel(link=PcieLink(gen=args.pcie_gen, lanes=args.pcie_lanes))

    rows, size_summary, matrix = bench_sizes(
        args.tiny, host, skip_oracle=args.no_verify, backend=backend)
    srv_rows, srv_summary, srv_matrix = bench_servers(
        args.tiny, host, backend=backend)
    rows += srv_rows
    matrix.update(srv_matrix)

    print_rows(rows)
    if args.json:
        resolved = None
        if backend is not None:
            from repro.backend import as_config
            resolved = as_config(backend).concrete().default
        write_bench_json(args.json, "hostmodel", rows, summary=dict(
            band_pct=list(BAND_PCT),
            pcie_reduction_pct={**size_summary, **srv_summary},
            monotone_in_splittable_share=True,
            pcie=dict(gen=args.pcie_gen, lanes=args.pcie_lanes),
        ), matrix=matrix, backend=resolved)


if __name__ == "__main__":
    main()
