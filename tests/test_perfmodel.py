"""Analytic performance model sanity against the paper's own numbers."""
import pytest

from repro.core.park import ParkConfig
from repro.switchsim import resources
from repro.switchsim.perfmodel import (GOODPUT_BYTES, ServerModel,
                                       TrafficDigest, digest, evaluate,
                                       peak_goodput)
from repro.traffic.generator import ENTERPRISE_MEAN, enterprise, fixed


class TestDigest:
    def test_256B_packets_paper_math(self):
        """Paper §6.2.2: 256B packets -> PayloadPark sends 103B packets."""
        d = digest([256], [1.0], park_bytes=160, min_park_len=160,
                   parking=True)
        assert d.mean_srv_bytes == pytest.approx(103.0)
        assert d.park_fraction == 1.0

    def test_enterprise_30pct_unparked(self):
        wl = enterprise()
        d = digest(wl.sizes, wl.probs, 160, 160, parking=True)
        assert d.park_fraction == pytest.approx(0.70, abs=0.02)
        assert wl.mean_pkt_bytes == pytest.approx(ENTERPRISE_MEAN)
        assert 850 < ENTERPRISE_MEAN < 920  # paper: avg ~882B


class TestEvaluate:
    def test_goodput_units(self):
        """Paper §6.1: 10 Mpps == 3.36 Gbps goodput (42B headers)."""
        m = ServerModel(link_gbps=40)
        d = digest([500], [1.0], 160, 160, parking=False)
        op = evaluate(m, d, nf_cycles=[50.0], send_gbps=40.0)
        assert op.pps == pytest.approx(10e6, rel=0.01)
        assert op.goodput_gbps == pytest.approx(3.36, rel=0.01)

    def test_pcie_transaction_cap(self):
        """Paper §6.2.2: '26 Gbps accommodates 31 million 103 byte packets';
        the NIC cannot run 40GE below ~170B packets."""
        # isolate the NIC: no framework/cpu caps
        m = ServerModel(framework_mpps=1000.0)
        d_small = digest([160 + 42], [1.0], 160, 160, parking=True)  # 49B
        op = evaluate(m, d_small, [5.0], send_gbps=40.0)
        assert op.bottleneck == "pcie_txn"
        d170 = digest([170], [1.0], 160, 160, parking=False)
        cap_pps = m.pcie_mpps * 1e6
        assert 40e9 / (170 * 8) <= cap_pps  # 170B just fits 40GE

    def test_parking_improves_peak_goodput(self):
        """Fixed 384..1492B packets: goodput gain in the paper's 10-36%
        band (Fig. 8)."""
        m = ServerModel(link_gbps=40)
        for size in (384, 512, 1024, 1492):
            chain = [46.0, 80.0]  # FW(1 rule) -> NAT
            base = peak_goodput(m, digest([size], [1.0], 160, 160, False),
                                chain)
            park = peak_goodput(m, digest([size], [1.0], 160, 160, True),
                                chain, parking=True,
                                table_capacity=40_000, max_exp=1)
            gain = park.goodput_gbps / base.goodput_gbps - 1
            assert 0.05 < gain < 0.60, (size, gain)

    def test_no_latency_penalty_below_saturation(self):
        """Paper Fig. 7: before baseline saturation, PayloadPark latency is
        within a microsecond of baseline."""
        m = ServerModel(link_gbps=10)
        wl = enterprise()
        d_base = digest(wl.sizes, wl.probs, 160, 160, False)
        d_park = digest(wl.sizes, wl.probs, 160, 160, True)
        for rate in (2.0, 4.0, 6.0, 8.0):
            b = evaluate(m, d_base, [160.0, 80.0, 120.0], rate)
            p = evaluate(m, d_park, [160.0, 80.0, 120.0], rate)
            assert p.latency_us <= b.latency_us + 1.0

    def test_compute_bound_nf_heavy_no_gain(self):
        """Paper §6.3.3: NF-Heavy with small packets is compute bound; no
        goodput gain from parking."""
        m = ServerModel(link_gbps=40)
        base = peak_goodput(m, digest([512], [1.0], 160, 160, False), [570.0])
        park = peak_goodput(m, digest([512], [1.0], 160, 160, True), [570.0],
                            parking=True, table_capacity=40_000)
        assert base.bottleneck == "cpu"
        assert park.goodput_gbps / base.goodput_gbps < 1.02


class TestRecircLatency:
    def test_expected_passes_term(self):
        """Latency charges recirc_latency_us per expected pass, not a flat
        constant."""
        m = ServerModel()
        base = TrafficDigest(500.0, 300.0, 1.0, recirc_per_pkt=0.0)
        two = TrafficDigest(500.0, 300.0, 1.0, recirc_per_pkt=2.0)
        l0 = evaluate(m, base, [50.0], 5.0).latency_us
        l2 = evaluate(m, two, [50.0], 5.0).latency_us
        assert l2 - l0 == pytest.approx(2 * m.recirc_latency_us)

    def test_digest_counts_second_pass_packets(self):
        """352B parking with a 160B pass width: every parked packet wider
        than one pass takes exactly one recirculation (DESIGN.md §6)."""
        d = digest([512], [1.0], 352, 160, True, pass_bytes=160)
        assert d.recirc_per_pkt == pytest.approx(1.0)
        assert d.mean_srv_bytes == pytest.approx(512 - 352 + 7)
        # payload below the pass width: no recirculation needed
        d2 = digest([160 + 42], [1.0], 352, 160, True, pass_bytes=160)
        assert d2.recirc_per_pkt == 0.0
        # and no pass model -> no term
        d3 = digest([512], [1.0], 352, 160, True)
        assert d3.recirc_per_pkt == 0.0


class TestResources:
    def test_table1_band(self):
        """Resource model lands in the paper's Table 1 band: avg SRAM ~26%/
        38% for 4/8 servers, peak < 50%, PHV < 45%."""
        cfg = ParkConfig(capacity=8192)
        u4 = resources.utilization(cfg, nf_servers=1)  # 1 server/pipe x4 pipes
        u8 = resources.utilization(cfg, nf_servers=2)  # 2 servers/pipe
        assert u4.sram_avg_pct < u8.sram_avg_pct
        assert u8.sram_peak_pct < 100.0
        assert u4.phv_pct < 45.0
        assert u4.vliw_pct < 20.0

    def test_capacity_memory_inversion(self):
        cfg = ParkConfig()
        slots = resources.capacity_for_memory_fraction(0.26, cfg)
        # 26% of a 15.36MB pipe at ~166B/slot, block-rounded ~= 23.5k slots
        assert 15_000 < slots < 30_000

    @pytest.mark.parametrize("frac", [0.10, 0.26, 0.40])
    @pytest.mark.parametrize("servers", [1, 2])
    def test_inversion_roundtrips_against_forward_model(self, frac, servers):
        """Fig. 14 inversion must agree with utilization(): the returned
        capacity is the largest whose block-placed cost fits the budget."""
        cfg = ParkConfig()
        budget = frac * resources.PIPE_SRAM_BYTES
        c = resources.capacity_for_memory_fraction(frac, cfg, servers)
        assert c > 0
        fits = resources.utilization(
            ParkConfig(capacity=c), nf_servers=servers).sram_bytes
        over = resources.utilization(
            ParkConfig(capacity=c + 1), nf_servers=servers).sram_bytes
        assert fits <= budget < over

    def test_recirc_rows_cost_more_sram(self):
        """352B rows need ~2.2x the banks of 160B rows."""
        c160 = resources.capacity_for_memory_fraction(0.26, ParkConfig())
        c352 = resources.capacity_for_memory_fraction(
            0.26, ParkConfig(recirculation=True))
        assert c352 < c160
