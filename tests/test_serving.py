"""Serving pool (PayloadPark-at-page-granularity) + engine lifecycle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install .[test])")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro import configs
from repro.configs.reduced import reduced
from repro.core import counters as C
from repro.models.lm import LM
from repro.serving import pool as P
from repro.serving.engine import (EngineConfig, RequestHeader, ServeEngine,
                                  parked_payload_bytes)
from repro.serving.pool import PoolConfig


class TestPool:
    def test_alloc_unique_pages(self):
        cfg = PoolConfig(num_pages=32)
        s = P.init_pool(cfg)
        s, pages, gens, ok = P.alloc(cfg, s, jnp.ones((16,), bool))
        assert bool(ok.all())
        assert len(set(map(int, pages))) == 16
        assert bool((gens > 0).all())

    def test_release_then_realloc(self):
        cfg = PoolConfig(num_pages=8, max_exp=5)
        s = P.init_pool(cfg)
        s, pages, gens, ok = P.alloc(cfg, s, jnp.ones((8,), bool))
        s = P.release(cfg, s, pages, gens)
        assert int(P.occupancy(s)) == 0
        s, pages2, _, ok2 = P.alloc(cfg, s, jnp.ones((8,), bool))
        assert bool(ok2.all())

    def test_eviction_invalidates_generation(self):
        cfg = PoolConfig(num_pages=4, max_exp=1)
        s = P.init_pool(cfg)
        s, pages, gens, _ = P.alloc(cfg, s, jnp.ones((4,), bool))
        s, _, _, _ = P.alloc(cfg, s, jnp.ones((4,), bool))  # evicts round 1
        assert not bool(P.validate(s, pages, gens))
        s2 = P.release(cfg, s, pages, gens)
        assert C.as_dict(s2.counters)["premature_evictions"] == 4

    def test_full_pool_fails_allocation(self):
        cfg = PoolConfig(num_pages=4, max_exp=10)
        s = P.init_pool(cfg)
        s, _, _, ok1 = P.alloc(cfg, s, jnp.ones((4,), bool))
        s, _, _, ok2 = P.alloc(cfg, s, jnp.ones((4,), bool))
        assert bool(ok1.all()) and not bool(ok2.any())

    @settings(max_examples=15, deadline=None)
    @given(ops=st.lists(st.booleans(), min_size=1, max_size=30))
    def test_property_conservation(self, ops):
        """splits == merges + evictions + occupancy, for any alloc/release
        interleaving."""
        cfg = PoolConfig(num_pages=8, max_exp=2)
        s = P.init_pool(cfg)
        held = []
        for do_alloc in ops:
            if do_alloc or not held:
                s, pg, gn, ok = P.alloc(cfg, s, jnp.ones((1,), bool))
                if bool(ok[0]):
                    held.append((int(pg[0]), int(gn[0])))
            else:
                pg, gn = held.pop()
                s = P.release(cfg, s, jnp.asarray([pg]), jnp.asarray([gn]))
        d = C.as_dict(s.counters)
        # every successful alloc was merged, evicted, or is still parked
        assert d["splits"] == d["merges"] + d["evictions"] + int(P.occupancy(s))


class TestEngine:
    @pytest.fixture(scope="class")
    def engine_setup(self):
        cfg = reduced(configs.get("gemma-7b"))
        lm = LM(cfg, remat_policy="off")
        params = lm.init_params(jax.random.key(0))
        return lm, params

    def test_lifecycle_and_header_accounting(self, engine_setup):
        lm, params = engine_setup
        eng = ServeEngine(lm, params, EngineConfig(
            max_batch=4, max_pages_per_req=8,
            pool=PoolConfig(num_pages=64, page_tokens=4)))
        assert eng.admit(1, [1, 2, 3, 4, 5])
        assert eng.admit(2, [9, 8])
        for _ in range(3):
            eng.step()
        out = eng.finish(1)
        assert len(out) == 5 + 1 + 3  # prompt + greedy tokens per step
        stats = eng.stats()
        assert stats["header_bytes"] > 0
        # the whole point: headers are orders of magnitude smaller than the
        # payload they replace on the wire
        assert stats["goodput_gain"] > 10
        eng.finish(2, cancel=True)
        assert eng.stats()["explicit_drops"] > 0
        assert eng.stats()["occupancy"] == 0

    def test_engine_matches_full_forward(self, engine_setup):
        lm, params = engine_setup
        eng = ServeEngine(lm, params, EngineConfig(
            max_batch=2, max_pages_per_req=8,
            pool=PoolConfig(num_pages=64, page_tokens=4)))
        toks = [3, 1, 4, 1, 5]
        eng.active[0] = True
        eng.rid[0] = 7
        eng.finished[7] = []
        logits_full, _ = lm.forward_train(
            params, {"tokens": jnp.asarray([toks], jnp.int32)})
        for i, t in enumerate(toks):
            assert eng._ensure_page(0)
            lg, kn, vn = eng._forward_token(0, t)
            eng._write_kv(0, kn, vn)
            eng.pos[0] += 1
            err = float(jnp.max(jnp.abs(
                lg.astype(jnp.float32)
                - logits_full[0, i].astype(jnp.float32))))
            assert err < 0.08, (i, err)

    def test_header_vs_payload_bytes(self):
        cfg = configs.get("deepseek-v2-236b")
        h = RequestHeader(1, 5, 32768, np.arange(256, dtype=np.int32),
                          np.ones(256, np.int32))
        assert h.wire_bytes() < 3000
        # MLA latent payload at 32k tokens is megabytes
        assert parked_payload_bytes(cfg, 32768) > 1e9
