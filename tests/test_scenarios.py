"""Scenario matrix subsystem: registry, grid expansion, vmapped-batch
equivalence with solo engine runs, schema-v2 artifacts, and the
compare.py benchmark-regression gate (DESIGN.md §8)."""
import json

import numpy as np
import pytest

import repro.scenarios as S
from repro.scenarios.spec import compile_key
from repro.switchsim import engine as E

from benchmarks import compare
from benchmarks.artifacts import (SCHEMA_VERSION, BenchArtifactError,
                                  load_bench_json, write_bench_json)
from benchmarks.figures import sec7_chain_table


def _mini(**kw) -> S.ScenarioSpec:
    kw.setdefault("name", "mini")
    kw.setdefault("workload", ("fixed", 512))
    kw.setdefault("chain", ("macswap",))
    kw.setdefault("capacity", 64)
    kw.setdefault("packets", 128)
    kw.setdefault("chunk", 32)
    kw.setdefault("window", 1)
    kw.setdefault("pmax", 512)
    return S.ScenarioSpec(**kw)


class TestSpec:
    def test_registry_has_the_paper_matrix(self):
        assert {"pipeline", "recirc", "hostmodel_sizes",
                "hostmodel_servers", "chain"} <= set(S.names())

    @pytest.mark.parametrize("fam", S.names())
    @pytest.mark.parametrize("tiny", [True, False])
    def test_families_expand_with_unique_names(self, fam, tiny):
        specs = S.family(fam, tiny=tiny)
        assert specs
        assert len({s.name for s in specs}) == len(specs)

    def test_unknown_family_raises_with_known_names(self):
        with pytest.raises(KeyError, match="registered"):
            S.family("nope")

    def test_grid_expansion(self):
        specs = S.grid(_mini(), "c{capacity}_p{pipes}",
                       capacity=[32, 64], pipes=[1, 2])
        assert [s.name for s in specs] == [
            "c32_p1", "c32_p2", "c64_p1", "c64_p2"]
        assert specs[1].capacity == 32 and specs[1].pipes == 2

    def test_grid_rejects_unknown_axis_and_colliding_names(self):
        with pytest.raises(ValueError, match="unknown grid axis"):
            S.grid(_mini(), "x{bogus}", bogus=[1])
        with pytest.raises(ValueError, match="does not separate"):
            S.grid(_mini(), "same", capacity=[32, 64])

    def test_spec_validates_eagerly(self):
        with pytest.raises(ValueError, match="multiple"):
            _mini(packets=100, chunk=32)
        with pytest.raises(ValueError, match="unknown workload"):
            _mini(workload=("bogus",))
        with pytest.raises(ValueError, match="unknown NF"):
            _mini(chain=("fw", "bogus"))

    def test_make_packets_deterministic_and_flow_constrained(self):
        spec = _mini(flows=16)
        a, b = S.make_packets(spec), S.make_packets(spec)
        np.testing.assert_array_equal(np.asarray(a.payload),
                                      np.asarray(b.payload))
        np.testing.assert_array_equal(np.asarray(a.src_ip),
                                      np.asarray(b.src_ip))
        assert len(np.unique(np.asarray(a.src_ip))) <= 16

    def test_workload_identity_independent_of_shape_axes(self):
        """Recirc on/off pairs must compare the same offered packets."""
        a = S.make_packets(_mini())
        b = S.make_packets(_mini(recirc=True, capacity=32))
        np.testing.assert_array_equal(np.asarray(a.payload),
                                      np.asarray(b.payload))

    def test_datacenter_workload_distinct_from_enterprise(self):
        dc = S.resolve_workload(("datacenter",))
        ent = S.resolve_workload(("enterprise",))
        assert dc.name == "datacenter"
        # DC-side mix: smaller mean, bigger not-splittable small-packet mass
        assert dc.mean_pkt_bytes < ent.mean_pkt_bytes
        assert not np.array_equal(dc.sizes, ent.sizes) or \
            not np.array_equal(dc.probs, ent.probs)


class TestRunner:
    def test_batched_points_equal_solo_engine_runs(self):
        """Points sharing a compile key run as ONE vmapped program and must
        be bit-identical to their solo run_engine results."""
        specs = [_mini(name="w512", workload=("fixed", 512)),
                 _mini(name="w256", workload=("fixed", 256)),
                 _mini(name="ent", workload=("enterprise",), seed=3)]
        results = S.run_matrix(specs)
        assert all(r.group_size == 3 for r in results)
        for spec, res in zip(specs, results):
            pkts = S.make_packets(spec)
            chain = S.build_chain(spec, pkts)
            from repro.core.packet import to_time_major
            solo = E.run_engine(spec.park_config(), chain,
                                to_time_major(pkts, spec.chunk),
                                window=spec.window)
            assert res.counters == solo.counters
            assert res.telemetry == solo.telemetry
            assert res.peak_occupancy == solo.peak_occupancy
            assert res.gain == E.goodput_gain(solo)

    def test_shape_axes_split_compile_groups(self):
        specs = [_mini(name="c64"), _mini(name="c32", capacity=32)]
        results = S.run_matrix(specs)
        assert [r.group_size for r in results] == [1, 1]
        pkts = S.make_packets(specs[0])
        chain = S.build_chain(specs[0], pkts)
        k0 = compile_key(specs[0], chain, 4)
        k1 = compile_key(specs[1], chain, 4)
        assert k0 != k1

    def test_multi_pipe_points_batch_on_flat_pipe_axis(self):
        """Two 2-pipe points share one compile; per-scenario regrouping
        must match the per-spec run_pipes results exactly."""
        specs = [_mini(name="a", pipes=2, packets=256, seed=0),
                 _mini(name="b", pipes=2, packets=256, seed=7)]
        results = S.run_matrix(specs)
        assert all(r.group_size == 2 for r in results)
        for spec, res in zip(specs, results):
            pkts = S.make_packets(spec)
            chain = S.build_chain(spec, pkts)
            traces, _ = S.steer(spec, pkts)
            solo = E.run_pipes(spec.park_config(), chain, traces,
                               window=spec.window)
            assert res.counters == solo.counters
            assert res.telemetry == solo.telemetry
            assert res.per_pipe_telemetry == solo.per_pipe_telemetry
            assert res.per_pipe_peak_occupancy == \
                solo.per_pipe_peak_occupancy

    def test_verify_oracle_passes_on_honest_results(self):
        res = S.run_matrix([_mini(name="v")])
        S.verify_oracle(res[0])

    def test_verify_oracle_rejects_tampered_results(self):
        res = S.run_matrix([_mini(name="t")])[0]
        bad = dict(res.per_pipe_counters[0])
        bad["splits"] += 1
        res.per_pipe_counters[0] = bad
        with pytest.raises(S.OracleMismatch, match="counters"):
            S.verify_oracle(res)


class TestArtifactsV2:
    def _payload(self, tmp_path, rows, bench="chain"):
        path = tmp_path / f"BENCH_{bench}.json"
        write_bench_json(str(path), bench, rows,
                         matrix={"s": _mini().as_dict()})
        return str(path)

    def test_schema_v2_roundtrip_with_scenario_rows(self, tmp_path):
        res = S.run_matrix([_mini(name="r")])[0]
        rows = S.default_rows(res, "fam")
        path = self._payload(tmp_path, rows, bench="fam")
        payload = load_bench_json(path)
        assert payload["schema"] == SCHEMA_VERSION == 2
        assert payload["rows"][0]["scenario"] == "r"
        assert payload["matrix"]["s"]["chain"] == ["macswap"]

    def test_v1_artifacts_are_rejected(self, tmp_path):
        path = tmp_path / "BENCH_old.json"
        path.write_text(json.dumps(
            {"schema": 1, "bench": "old", "rows": [], "summary": {}}))
        with pytest.raises(BenchArtifactError, match="schema"):
            load_bench_json(str(path))

    def test_duplicate_row_names_are_rejected(self, tmp_path):
        path = tmp_path / "BENCH_dup.json"
        path.write_text(json.dumps(
            {"schema": 2, "bench": "d",
             "rows": [{"name": "x", "value": 1},
                      {"name": "x", "value": 2}]}))
        with pytest.raises(BenchArtifactError, match="duplicate"):
            load_bench_json(str(path))


class TestCompareGate:
    ROWS = [("f/a/goodput_gain", 0.20, "d", None),
            ("f/a/wire_bytes", 1000, "d", None),
            ("f/a/pps", 123456, "timing", None),
            ("f/a/oracle_identical", 1, "d", None)]

    def _write(self, tmp_path, name, rows, bench="f", schema=None):
        path = tmp_path / name
        payload = write_bench_json(str(path), bench, rows)
        if schema is not None:
            payload["schema"] = schema
            path.write_text(json.dumps(payload))
        return str(path)

    def test_identical_artifacts_pass(self, tmp_path):
        base = self._write(tmp_path, "base.json", self.ROWS)
        cand = self._write(tmp_path, "cand.json", self.ROWS)
        assert compare.compare_files(base, cand) == []

    def test_injected_regression_fails(self, tmp_path):
        base_dir = tmp_path / "baselines"
        base_dir.mkdir()
        base = self._write(base_dir, "BENCH_f.json", self.ROWS)
        bad = [("f/a/goodput_gain", 0.10, "d", None)] + self.ROWS[1:]
        cand = self._write(tmp_path, "BENCH_f.json", bad)
        problems = compare.compare_files(base, cand)
        assert len(problems) == 1 and "goodput_gain" in problems[0]
        assert compare.main([cand, "--baselines", str(base_dir)]) == 1

    def test_timing_rows_are_not_gated(self, tmp_path):
        base = self._write(tmp_path, "base.json", self.ROWS)
        fast = self.ROWS[:2] + [("f/a/pps", 999, "t", None), self.ROWS[3]]
        cand = self._write(tmp_path, "cand.json", fast)
        assert compare.compare_files(base, cand) == []

    def test_exactness_rows_gate_bit_for_bit(self, tmp_path):
        base = self._write(tmp_path, "base.json", self.ROWS)
        bad = self.ROWS[:3] + [("f/a/oracle_identical", 0, "d", None)]
        cand = self._write(tmp_path, "cand.json", bad)
        assert any("oracle_identical" in p
                   for p in compare.compare_files(base, cand))

    def test_missing_row_fails_and_new_row_warns(self, tmp_path):
        base = self._write(tmp_path, "base.json", self.ROWS)
        cand = self._write(tmp_path, "cand.json",
                           self.ROWS[1:] + [("f/a/extra", 1, "d", None)])
        problems = compare.compare_files(base, cand)
        assert any(p.startswith("MISSING") for p in problems)
        assert any(p.startswith("NEW") for p in problems)
        # NEW rows alone must not fail the gate
        cand2 = self._write(tmp_path, "cand2.json",
                            self.ROWS + [("f/a/extra", 1, "d", None)])
        probs2 = compare.compare_files(base, cand2)
        assert all(p.startswith("NEW") for p in probs2)

    def test_schema_mismatch_exits_2(self, tmp_path):
        base = self._write(tmp_path, "BENCH_f.json", self.ROWS)
        bad_dir = tmp_path / "cand"
        bad_dir.mkdir()
        cand = self._write(bad_dir, "BENCH_f.json", self.ROWS, schema=1)
        assert compare.main([cand, "--baselines", str(tmp_path)]) == 2

    def test_bench_name_mismatch(self, tmp_path):
        base = self._write(tmp_path, "base.json", self.ROWS, bench="f")
        cand = self._write(tmp_path, "cand.json", self.ROWS, bench="g")
        assert any("bench name" in p
                   for p in compare.compare_files(base, cand))

    def test_tolerance_rules_have_a_catch_all(self):
        rtol, atol = compare.tolerance_for("completely/unknown/metric")
        assert rtol is not None

    def test_committed_baselines_are_valid_schema_v2(self):
        import glob
        import os
        here = os.path.join(os.path.dirname(compare.__file__), "baselines")
        paths = glob.glob(os.path.join(here, "BENCH_*.json"))
        assert len(paths) >= 4  # pipeline, recirc, hostmodel, chain
        for p in paths:
            load_bench_json(p)


class TestFiguresConsume:
    def _chain_rows(self):
        rows = []
        for wl in ("datacenter", "enterprise"):
            rows.append((f"chain/{wl}_base/goodput_gain", 0.13, "d", None))
            rows.append((f"chain/{wl}_recirc/goodput_gain", 0.22, "d", None))
        return rows

    def test_sec7_table_renders_from_artifact(self, tmp_path):
        path = tmp_path / "BENCH_chain.json"
        write_bench_json(str(path), "chain", self._chain_rows())
        lines = sec7_chain_table(load_bench_json(str(path)))
        assert any("datacenter" in ln for ln in lines)
        assert any("13.00%" in ln for ln in lines)

    def test_missing_referenced_scenario_row_is_fatal(self, tmp_path):
        rows = self._chain_rows()[1:]  # drop the datacenter base-gain row
        path = tmp_path / "BENCH_chain.json"
        write_bench_json(str(path), "chain", rows)
        with pytest.raises(BenchArtifactError, match="missing referenced"):
            sec7_chain_table(load_bench_json(str(path)))

    def test_figures_main_exits_nonzero_without_chain(self, tmp_path):
        from benchmarks.figures import main as figures_main
        path = tmp_path / "BENCH_f.json"
        write_bench_json(str(path), "f", [("f/x", 1, "d", None)])
        with pytest.raises(SystemExit) as e:
            figures_main([str(path), "--require-chain"])
        assert e.value.code == 2
