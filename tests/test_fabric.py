"""Fabric sharding correctness (switchsim.fabric, DESIGN.md §12).

The headline contract is shard-count invariance: the same scenario run
with its pipe axis sharded over 1, 2 or 8 devices yields bit-identical
counters, telemetry and occupancy.  The multi-device tests run in
SUBPROCESSES because the device count must be fixed before jax
initializes (the main pytest process keeps 1 device for everything else);
each subprocess forces 8 host devices via XLA_FLAGS — the same recipe
``repro.distributed.force_host_devices`` applies programmatically, whose
own guard semantics are tested in-process below.
"""
import os
import subprocess
import sys
import textwrap
import warnings

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 900,
            force_env: bool = True):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    if force_env:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


PRELUDE = """
import warnings
import numpy as np
import repro.scenarios as S

def point(pipes, devices, packets=512, **kw):
    return S.pipeline_grid([pipes], packets=packets, chunk=64, window=2,
                           pmax=512, capacity=256, devices=(devices,),
                           **kw)[0]

def same(a, b):
    return (a.counters == b.counters
            and a.per_pipe_counters == b.per_pipe_counters
            and a.telemetry == b.telemetry
            and a.per_pipe_telemetry == b.per_pipe_telemetry
            and a.nf_counters == b.nf_counters
            and a.per_pipe_nf_counters == b.per_pipe_nf_counters
            and a.per_pipe_peak_occupancy == b.per_pipe_peak_occupancy
            and np.array_equal(np.asarray(a.per_pipe_occ_series),
                               np.asarray(b.per_pipe_occ_series)))
"""


def test_shard_count_invariance_1_2_8():
    """Bit-identical counters/telemetry/occupancy on 1, 2 and 8 devices,
    with the engine≡loop oracle green per shard on every device count."""
    run_sub(PRELUDE + """
import jax
assert len(jax.devices()) == 8, jax.devices()
res = {d: S.run_matrix([point(8, d)])[0] for d in (1, 2, 8)}
for d in (2, 8):
    assert same(res[d], res[1]), f"devices={d} diverged from devices=1"
    S.verify_oracle(res[d])   # per-pipe == per-shard (DESIGN.md §12)
print("invariance OK")
""")


def test_per_shard_oracle_recirc_modes_and_backends():
    """verify_oracle on sharded runs in both recirc modes x both backends."""
    run_sub(PRELUDE + """
import dataclasses
for backend in ("ref", "pallas_interpret"):
    for recirc in (False, True):
        spec = dataclasses.replace(
            point(4, 2, backends=(backend,)),
            name=f"fab_{backend}_{int(recirc)}", recirc=recirc)
        res = S.run_matrix([spec])[0]
        S.verify_oracle(res)
        ref = S.run_matrix([dataclasses.replace(spec, devices=1)])[0]
        assert same(res, ref), (backend, recirc)
print("oracle OK")
""")


def test_non_dividing_pipe_count_falls_back():
    """pipes=3 over 2 devices warns and equals the single-device run."""
    run_sub(PRELUDE + """
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    r3 = S.run_matrix([point(3, 2, packets=384)])[0]
assert any("does not divide" in str(x.message) for x in w), \
    [str(x.message) for x in w]
ref = S.run_matrix([point(3, 1, packets=384)])[0]
assert same(r3, ref)
print("fallback OK")
""")


def test_run_matrix_group_spans_devices():
    """Two same-compile-key specs at devices=2 batch into ONE sharded
    program (their concatenated pipe axis spans the devices) and match
    their solo runs bit-for-bit."""
    run_sub(PRELUDE + """
import dataclasses
# flows>0 draws firewall rules from the deterministic pool instead of the
# traffic, so the two seeds share one chain and hence one compile key
a = dataclasses.replace(point(2, 2), name="a", seed=0, flows=256)
b = dataclasses.replace(point(2, 2), name="b", seed=7, flows=256)
together = S.run_matrix([a, b])
assert together[0].group_size == 2, "specs did not share a compile group"
solo = [S.run_matrix([s])[0] for s in (a, b)]
for got, want in zip(together, solo):
    assert same(got, want), got.spec.name
print("group OK")
""")


def test_more_devices_than_visible_falls_back():
    """Requesting more devices than visible warns and runs replicated."""
    run_sub(PRELUDE + """
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    r = S.run_matrix([point(2, 4)])[0]
assert any("only 2 visible" in str(x.message) for x in w), \
    [str(x.message) for x in w]
ref = S.run_matrix([point(2, 1)])[0]
assert same(r, ref)
print("visibility fallback OK")
""", devices=2)


def test_force_host_devices_sets_flag_and_device_count():
    """force_host_devices before jax init yields that many devices, and
    replaces (not duplicates) a pre-existing force flag."""
    run_sub("""
import os
os.environ["XLA_FLAGS"] = \\
    "--xla_force_host_platform_device_count=3 --xla_dump_to=/dev/null"
from repro.distributed import force_host_devices
force_host_devices(5)
flags = os.environ["XLA_FLAGS"].split()
assert flags.count("--xla_force_host_platform_device_count=5") == 1, flags
assert not any(f.startswith("--xla_force_host_platform_device_count=3")
               for f in flags), flags
assert "--xla_dump_to=/dev/null" in flags, flags
import jax
assert len(jax.devices()) == 5, jax.devices()
print("force OK")
""", force_env=False)


def test_force_host_devices_raises_after_jax_init():
    run_sub("""
import jax, jax.numpy as jnp
jnp.zeros(2).block_until_ready()   # initializes the backend
from repro.distributed import force_host_devices
try:
    force_host_devices(8)
except RuntimeError as e:
    assert "already" in str(e) or "initialized" in str(e), e
else:
    raise SystemExit("force_host_devices did not raise after init")
print("guard OK")
""", force_env=False)


def test_force_host_devices_rejects_bad_count():
    from repro.distributed import force_host_devices
    with pytest.raises(ValueError):
        force_host_devices(0)


def test_spec_devices_validation_and_compile_key():
    """devices is validated and separates compile groups (a sharded
    program is a different XLA program)."""
    import dataclasses

    import repro.scenarios as S
    from repro.scenarios.spec import compile_key

    base = S.pipeline_grid([2], packets=128, chunk=64, window=2, pmax=512,
                           capacity=256)[0]
    with pytest.raises(ValueError, match="devices"):
        dataclasses.replace(base, devices=0)
    pkts = S.make_packets(base)
    chain = S.build_chain(base, pkts)
    k1 = compile_key(base, chain, steps=2)
    k2 = compile_key(dataclasses.replace(base, devices=2), chain, steps=2)
    assert k1 != k2
    assert k1 == compile_key(dataclasses.replace(base, seed=5), chain,
                             steps=2)


def test_resolve_devices_guards():
    """resolve_devices: trivial counts short-circuit without touching jax;
    non-dividing and oversubscribed requests fall back with a warning."""
    from repro.switchsim import fabric

    assert fabric.resolve_devices(8, None) == 1
    assert fabric.resolve_devices(8, 1) == 1
    assert fabric.resolve_devices(8, 0) == 1
    # the single in-process device: 2 > visible -> warn + fallback
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert fabric.resolve_devices(8, 2) == 1
    assert any("visible" in str(x.message) for x in w)
