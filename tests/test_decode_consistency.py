"""Prefill+decode must agree with the full forward pass — the cache/ring/
rope invariant, per architecture family.  MoE archs use a raised capacity
factor: expert-capacity token dropping legitimately depends on batch
composition (Switch-style dropping), so exactness requires no drops."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs.reduced import reduced
from repro.models.lm import LM

B, S = 2, 33  # prefill 32 + 1 decode


def _nodrop(cfg):
    if cfg.moe is not None:
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("arch", configs.names())
def test_prefill_decode_matches_forward(arch):
    cfg = _nodrop(reduced(configs.get(arch)))
    lm = LM(cfg, remat_policy="off")
    params = lm.init_params(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size,
                              dtype=jnp.int32)
    batch_full = {"tokens": toks}
    batch_pre = {"tokens": toks[:, :-1]}
    if cfg.family == "vlm":
        pos = jnp.broadcast_to(jnp.arange(S)[None, None],
                               (3, B, S)).astype(jnp.int32)
        batch_full["positions"] = pos
        batch_pre["positions"] = pos[:, :, :-1]
        ve = 0.02 * jax.random.normal(jax.random.key(2),
                                      (B, 8, cfg.d_model)).astype(jnp.bfloat16)
        batch_full["vision_embeds"] = ve
        batch_pre["vision_embeds"] = ve
    if cfg.enc_layers:
        fr = 0.1 * jax.random.normal(jax.random.key(3),
                                     (B, 32, cfg.d_model)).astype(jnp.bfloat16)
        batch_full["enc_frames"] = fr
        batch_pre["enc_frames"] = fr
    logits_full, _ = lm.forward_train(params, batch_full)
    want = logits_full[:, -1].astype(jnp.float32)
    _, cache = lm.prefill(params, batch_pre, cache_len=40)
    got, _ = lm.decode_step(params, cache, toks[:, -1],
                            jnp.full((B,), S - 1, jnp.int32))
    got = got.astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(got - want))) \
        / max(float(jnp.max(jnp.abs(want))), 1e-6)
    assert rel < 0.06, rel


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "recurrentgemma-9b"])
def test_ring_buffer_window_decode(arch):
    """Windowed archs: decoding far past the window with a ring cache must
    agree with the full forward (the ring IS the window)."""
    cfg = _nodrop(reduced(configs.get(arch)))
    lm = LM(cfg, remat_policy="off")
    params = lm.init_params(jax.random.key(0))
    total = 48  # window is 16 -> ring wraps 3x
    toks = jax.random.randint(jax.random.key(4), (B, total), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    logits_full, _ = lm.forward_train(params, {"tokens": toks})
    _, cache = lm.prefill(params, {"tokens": toks[:, :32]}, cache_len=40)
    got = None
    for i in range(32, total):
        got, cache = lm.decode_step(params, cache, toks[:, i],
                                    jnp.full((B,), i, jnp.int32))
    want = logits_full[:, -1].astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(got.astype(jnp.float32) - want))) \
        / max(float(jnp.max(jnp.abs(want))), 1e-6)
    assert rel < 0.08, rel
