"""Shallow NF behaviour tests (paper §6.1 NFs)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packet import make_udp_batch
from repro.nf.chain import Chain, to_explicit_drops
from repro.nf.firewall import Firewall
from repro.nf.macswap import MacSwap
from repro.nf.maglev import MaglevLB, build_table
from repro.nf.nat import Nat


def mk(key=0, n=64, size=300):
    return make_udp_batch(jax.random.key(key), n, size, pmax=512)


class TestFirewall:
    def test_blocks_listed_ips(self):
        p = mk()
        fw = Firewall(rules=(int(p.src_ip[0]), int(p.src_ip[3])))
        st = fw.init_state()
        _, out, drop, _ = fw(st, p)
        assert bool(drop[0]) and bool(drop[3])
        blocked = np.isin(np.asarray(p.src_ip), np.asarray(st))
        np.testing.assert_array_equal(np.asarray(drop), blocked)

    def test_never_touches_payload(self):
        p = mk()
        fw = Firewall(rules=(1, 2, 3))
        _, out, _, _ = fw(fw.init_state(), p)
        assert jnp.all(out.payload == p.payload)


class TestNat:
    def test_same_flow_same_mapping(self):
        nat = Nat()
        st = nat.init_state()
        p = mk(n=32, size=200)
        p = p.replace(src_ip=jnp.full((32,), 42, jnp.int32),
                      src_port=jnp.full((32,), 1000, jnp.int32))
        _, out, drop, _ = nat(st, p)
        assert not bool(drop.any())
        assert bool(jnp.all(out.src_ip == nat.nat_ip))
        assert len(set(map(int, out.src_port))) == 1  # one flow, one port

    def test_distinct_flows_distinct_ports(self):
        nat = Nat()
        st = nat.init_state()
        p = mk(n=64)
        st, out, drop, _ = nat(st, p)
        ports = np.asarray(out.src_port)[~np.asarray(drop)]
        assert len(set(ports.tolist())) == len(ports)

    def test_mapping_persists_across_batches(self):
        nat = Nat()
        st = nat.init_state()
        p = mk(n=8)
        st, out1, _, _ = nat(st, p)
        st, out2, _, _ = nat(st, p)  # same flows again
        np.testing.assert_array_equal(np.asarray(out1.src_port),
                                      np.asarray(out2.src_port))


class TestMaglev:
    def test_table_is_balanced(self):
        backends = tuple(range(8))
        table = build_table(backends, 251)
        counts = np.bincount(table, minlength=8)
        assert counts.min() >= 251 // 8 - 2 and counts.max() <= 251 // 8 + 2

    def test_flow_affinity(self):
        lb = MaglevLB()
        st = lb.init_state()
        p = mk(n=16)
        _, out1, _, _ = lb(st, p)
        _, out2, _, _ = lb(st, p)
        np.testing.assert_array_equal(np.asarray(out1.dst_ip),
                                      np.asarray(out2.dst_ip))
        assert np.isin(np.asarray(out1.dst_ip),
                       np.asarray(st["backend_ips"])).all()


class TestChain:
    def test_fw_nat_lb_chain(self):
        p = mk(n=64)
        chain = Chain((Firewall(rules=(int(p.src_ip[0]),)), Nat(), MaglevLB(),
                       MacSwap()))
        states = chain.init_state()
        _, out, dropped, cycles = chain.run(states, p)
        assert bool(dropped[0])
        assert cycles > 0
        # surviving packets: NAT'd, LB'd and MAC-swapped
        alive = np.asarray(out.alive)
        assert (np.asarray(out.src_ip)[alive] == 0x0A000001).all()
        np.testing.assert_array_equal(np.asarray(out.dst_mac)[alive],
                                      np.asarray(p.src_mac)[alive])

    def test_explicit_drop_conversion(self):
        p = mk(n=16)
        p = p.replace(pp_valid=jnp.ones((16,), bool),
                      pp_enb=jnp.ones((16,), jnp.int32))
        dropped = jnp.zeros((16,), bool).at[2].set(True).at[5].set(True)
        pkts = p.replace(alive=p.alive & ~dropped)
        out = to_explicit_drops(pkts, dropped)
        assert bool(out.alive[2]) and bool(out.alive[5])
        assert int(out.pp_op[2]) == 1
        assert int(out.payload_len[2]) == 0
