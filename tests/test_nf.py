"""Shallow NF behaviour tests (paper §6.1 NFs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packet import make_udp_batch
from repro.nf.chain import Chain, to_explicit_drops
from repro.nf.firewall import Firewall
from repro.nf.macswap import MacSwap
from repro.nf.maglev import MaglevLB, build_table
from repro.nf.nat import Nat


def mk(key=0, n=64, size=300):
    return make_udp_batch(jax.random.key(key), n, size, pmax=512)


class TestFirewall:
    def test_blocks_listed_ips(self):
        p = mk()
        fw = Firewall(rules=(int(p.src_ip[0]), int(p.src_ip[3])))
        st = fw.init_state()
        _, out, drop, _ = fw(st, p)
        assert bool(drop[0]) and bool(drop[3])
        blocked = np.isin(np.asarray(p.src_ip), np.asarray(st))
        np.testing.assert_array_equal(np.asarray(drop), blocked)

    def test_never_touches_payload(self):
        p = mk()
        fw = Firewall(rules=(1, 2, 3))
        _, out, _, _ = fw(fw.init_state(), p)
        assert jnp.all(out.payload == p.payload)


class TestNat:
    def test_same_flow_same_mapping(self):
        nat = Nat()
        st = nat.init_state()
        p = mk(n=32, size=200)
        p = p.replace(src_ip=jnp.full((32,), 42, jnp.int32),
                      src_port=jnp.full((32,), 1000, jnp.int32))
        _, out, drop, _ = nat(st, p)
        assert not bool(drop.any())
        assert bool(jnp.all(out.src_ip == nat.nat_ip))
        assert len(set(map(int, out.src_port))) == 1  # one flow, one port

    def test_distinct_flows_distinct_ports(self):
        nat = Nat()
        st = nat.init_state()
        p = mk(n=64)
        st, out, drop, _ = nat(st, p)
        ports = np.asarray(out.src_port)[~np.asarray(drop)]
        assert len(set(ports.tolist())) == len(ports)

    def test_mapping_persists_across_batches(self):
        nat = Nat()
        st = nat.init_state()
        p = mk(n=8)
        st, out1, _, _ = nat(st, p)
        st, out2, _, _ = nat(st, p)  # same flows again
        np.testing.assert_array_equal(np.asarray(out1.src_port),
                                      np.asarray(out2.src_port))

    def test_ports_stay_in_uint16_range_under_churn(self):
        """Regression: the seed's monotonic port counter overflowed 65535
        after enough flows.  Ports are now slot-owned and bounded."""
        nat = Nat(capacity=64, base_port=65400, max_exp=1)
        st = nat.init_state()
        top = 65400 + 64 - 1
        assert top <= 65535
        last_mapped = 0
        for r in range(10):  # 640 distinct flows through 64 slots
            p = mk(key=100 + r, n=64)
            st, out, drop, _ = nat(st, p)
            ok = ~np.asarray(drop)
            ports = np.asarray(out.src_port)[ok]
            assert ports.min() >= 65400 and ports.max() <= top
            last_mapped = int(ok.sum())
        # expiry keeps reclaiming slots: churn never starves permanently
        assert last_mapped > 0

    def test_port_space_overflow_rejected(self):
        with pytest.raises(ValueError):
            Nat(capacity=1 << 14, base_port=60000)  # tops out past 65535
        with pytest.raises(ValueError):
            Nat(capacity=4)  # below probe depth

    def test_flow_expiry_reclaims_slots(self):
        """A full table ages under failed inserts (EXP-style); new flows
        eventually claim the expired slots instead of dropping forever."""
        nat = Nat(capacity=8, base_port=10000, max_exp=1)
        st = nat.init_state()
        p1 = mk(key=200, n=8)
        st, _, drop1, _ = nat(st, p1)
        assert not bool(drop1.any())          # 8 flows fill all 8 slots
        p2 = mk(key=201, n=8)                 # 8 fresh flows, table full
        st, _, drop2, _ = nat(st, p2)
        st, out3, drop3, _ = nat(st, p2)      # aged slots now reclaimable
        assert int(np.asarray(drop3).sum()) < int(np.asarray(drop2).sum())
        ports = np.asarray(out3.src_port)[~np.asarray(drop3)]
        assert ports.min() >= 10000 and ports.max() <= 10007


class TestMaglev:
    def test_table_is_balanced(self):
        backends = tuple(range(8))
        table = build_table(backends, 251)
        counts = np.bincount(table, minlength=8)
        assert counts.min() >= 251 // 8 - 2 and counts.max() <= 251 // 8 + 2

    def test_flow_affinity(self):
        lb = MaglevLB()
        st = lb.init_state()
        p = mk(n=16)
        _, out1, _, _ = lb(st, p)
        _, out2, _, _ = lb(st, p)
        np.testing.assert_array_equal(np.asarray(out1.dst_ip),
                                      np.asarray(out2.dst_ip))
        assert np.isin(np.asarray(out1.dst_ip),
                       np.asarray(st["backend_ips"])).all()


class TestChain:
    def test_fw_nat_lb_chain(self):
        p = mk(n=64)
        chain = Chain((Firewall(rules=(int(p.src_ip[0]),)), Nat(), MaglevLB(),
                       MacSwap()))
        states = chain.init_state()
        _, out, dropped, cycles = chain.run(states, p)
        assert bool(dropped[0])
        assert cycles > 0
        # surviving packets: NAT'd, LB'd and MAC-swapped
        alive = np.asarray(out.alive)
        assert (np.asarray(out.src_ip)[alive] == 0x0A000001).all()
        np.testing.assert_array_equal(np.asarray(out.dst_mac)[alive],
                                      np.asarray(p.src_mac)[alive])

    def test_explicit_drop_conversion(self):
        p = mk(n=16)
        p = p.replace(pp_valid=jnp.ones((16,), bool),
                      pp_enb=jnp.ones((16,), jnp.int32))
        dropped = jnp.zeros((16,), bool).at[2].set(True).at[5].set(True)
        pkts = p.replace(alive=p.alive & ~dropped)
        out = to_explicit_drops(pkts, dropped)
        assert bool(out.alive[2]) and bool(out.alive[5])
        assert int(out.pp_op[2]) == 1
        assert int(out.payload_len[2]) == 0
