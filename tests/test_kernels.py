"""Per-kernel allclose vs the pure-jnp oracle, with shape/dtype sweeps
(interpret=True executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.acl_match.ops import acl_match
from repro.kernels.acl_match.ref import acl_match_ref
from repro.kernels.crc16.ops import crc16_tag_kernel_op
from repro.kernels.crc16.ref import crc16_tag_ref
from repro.kernels.maglev.ops import maglev_select
from repro.kernels.maglev.ref import maglev_select_ref
from repro.kernels.paged_attention.ops import paged_decode_attention
from repro.kernels.paged_attention.ref import paged_decode_attention_ref
from repro.kernels.payload_fetch.ops import payload_fetch
from repro.kernels.payload_fetch.ref import payload_fetch_ref
from repro.kernels.payload_store.ops import payload_store
from repro.kernels.payload_store.ref import payload_store_ref
from repro.kernels.payload_store.ops import _to_words, _to_bytes


def rand_table(key, m, nbytes):
    return jax.random.randint(key, (m, nbytes), 0, 256,
                              dtype=jnp.int32).astype(jnp.uint8)


@pytest.mark.parametrize("m,nbytes,b", [(16, 160, 8), (64, 352, 24),
                                        (128, 160, 128), (32, 32, 5)])
def test_payload_store_sweep(m, nbytes, b):
    ks = jax.random.split(jax.random.key(0), 4)
    table = rand_table(ks[0], m, nbytes)
    payload = rand_table(ks[1], b, nbytes)
    idx = jax.random.permutation(ks[2], m)[:b] if b <= m else \
        jnp.arange(b) % m
    enb = jax.random.bernoulli(ks[3], 0.7, (b,))
    got = payload_store(table, payload, idx, enb)
    want_w = payload_store_ref(_to_words(table), _to_words(payload), idx, enb)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(_to_bytes(want_w, nbytes)))


@pytest.mark.parametrize("m,nbytes,b", [(16, 160, 8), (64, 352, 24),
                                        (128, 160, 128)])
def test_payload_fetch_sweep(m, nbytes, b):
    ks = jax.random.split(jax.random.key(1), 3)
    table = rand_table(ks[0], m, nbytes)
    idx = jax.random.permutation(ks[1], m)[:b] if b <= m else \
        jnp.arange(b) % m
    mask = jax.random.bernoulli(ks[2], 0.6, (b,))
    got_rows, got_table = payload_fetch(table, idx, mask)
    want_rows_w, want_table_w = payload_fetch_ref(_to_words(table), idx, mask)
    np.testing.assert_array_equal(
        np.asarray(got_rows), np.asarray(_to_bytes(want_rows_w, nbytes)))
    np.testing.assert_array_equal(
        np.asarray(got_table), np.asarray(_to_bytes(want_table_w, nbytes)))


@pytest.mark.parametrize("n", [1, 7, 1000, 1024])
def test_crc16_sweep(n):
    ks = jax.random.split(jax.random.key(2), 2)
    ti = jax.random.randint(ks[0], (n,), 0, 1 << 16, dtype=jnp.int32)
    clk = jax.random.randint(ks[1], (n,), 1, 1 << 16, dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(crc16_tag_kernel_op(ti, clk)),
        np.asarray(crc16_tag_ref(ti, clk)))


def test_crc16_known_vector():
    # CRC-16/CCITT-FALSE("123456789") = 0x29B1; check our byte routine
    from repro.core.header import crc16_bytes
    data = jnp.asarray([ord(c) for c in "123456789"], jnp.int32)
    assert int(crc16_bytes(data)) == 0x29B1


@pytest.mark.parametrize("b,r", [(5, 1), (500, 20), (1024, 4)])
def test_acl_match_sweep(b, r):
    ks = jax.random.split(jax.random.key(3), 2)
    ips = jax.random.randint(ks[0], (b,), 0, 50, dtype=jnp.int32)
    rules = jax.random.randint(ks[1], (r,), 0, 50, dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(acl_match(ips, rules)),
                                  np.asarray(acl_match_ref(ips, rules)))


@pytest.mark.parametrize("b", [3, 300])
def test_maglev_sweep(b):
    from repro.nf.maglev import MaglevLB
    from repro.core.packet import make_udp_batch
    lb = MaglevLB()
    st = lb.init_state()
    p = make_udp_batch(jax.random.key(4), b, 300, pmax=512)
    got = maglev_select(p.src_ip, p.dst_ip, p.src_port, p.dst_port, p.proto,
                        st["table"], st["backend_ips"])
    want = maglev_select_ref(p.src_ip, p.dst_ip, p.src_port, p.dst_port,
                             p.proto, st["table"], st["backend_ips"])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b,k,g,e,page,mp", [
    (4, 2, 4, 64, 16, 6),
    (2, 1, 8, 128, 128, 4),
    (8, 4, 1, 32, 8, 3),
])
def test_paged_attention_sweep(b, k, g, e, page, mp):
    npages = mp * b + 2
    ks = jax.random.split(jax.random.key(5), 5)
    q = jax.random.normal(ks[0], (b, k, g, e)).astype(jnp.bfloat16)
    kp = jax.random.normal(ks[1], (npages, page, k, e)).astype(jnp.bfloat16)
    vp = jax.random.normal(ks[2], (npages, page, k, e)).astype(jnp.bfloat16)
    rng = np.random.default_rng(0)
    pt = np.full((b, mp), -1, np.int32)
    lengths = np.zeros((b,), np.int32)
    for i in range(b):
        n = rng.integers(1, mp + 1)
        pt[i, :n] = rng.choice(npages, n, replace=False)
        lengths[i] = rng.integers(1, n * page + 1)
    got = paged_decode_attention(q, kp, vp, jnp.asarray(pt),
                                 jnp.asarray(lengths))
    want = paged_decode_attention_ref(q, kp, vp, jnp.asarray(pt),
                                      jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=0.02, rtol=0.05)
