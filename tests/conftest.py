import os

# Smoke tests and benches must see ONE device (the dry-run sets its own flag
# as the very first line of launch/dryrun.py).  Keep threads bounded for the
# single-core CI container.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "float32")
