"""Adversarial & failure families: property tests (DESIGN.md §10).

Property style: hypothesis ``@given`` strategies behind the repo's
module-level ``importorskip`` guard (the same idiom as test_core_park.py /
test_serving.py — CI installs hypothesis explicitly, local runs without it
skip).  Example counts are kept small because each example compiles or
runs a full scenario matrix; ``deadline=None`` for the same reason.

The properties:

  * wire-level drop rate is monotone in the attack fraction (the
    adversarial workload couples fractions through one permutation rank,
    so higher fractions are strict supersets of attack slots);
  * parked-slot occupancy never exceeds the configured capacity, at any
    step, on any pipe, under any attack mix;
  * engine ≡ host loop stays bit-exact (counters + telemetry + NF
    counters) across a randomly placed fault event, in both
    recirculation modes, on the ref and pallas_interpret backends;
  * the NAT stale-mapping rule (regression): an aged-out binding whose
    flow returns must count ``nat_stale_hits`` and drop — never silently
    translate — and the flow's next packet re-binds cleanly.
"""
import dataclasses

import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install .[test])")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

import repro.scenarios as S  # noqa: E402
from benchmarks import compare  # noqa: E402
from repro.core.packet import make_udp_batch  # noqa: E402
from repro.nf.nat import Nat  # noqa: E402
from repro.switchsim.faults import FaultSpec  # noqa: E402
from repro.traffic.generator import (ATTACK_SIZE, VICTIM_IP,  # noqa: E402
                                     adversarial, churn, enterprise,
                                     pipe_trace_steps)


def _exhaust_spec(frac, burst, seed=0, **kw):
    kw.setdefault("name", f"f{frac}_b{burst}_s{seed}")
    kw.setdefault("chain", ("macswap",))
    kw.setdefault("capacity", 32)   # inflight // 2
    kw.setdefault("max_exp", 2)
    kw.setdefault("packets", 128)
    kw.setdefault("chunk", 32)
    kw.setdefault("window", 2)
    kw.setdefault("pmax", 512)
    return S.ScenarioSpec(workload=("adversarial", "enterprise", frac, burst),
                          seed=seed, **kw)


def _drop_rate(r) -> float:
    t = r.telemetry
    return 1.0 - t.merged_pkts / t.wire_pkts


class TestAdversarialWorkload:
    def test_zero_fraction_is_bitexact_base_traffic(self):
        key = jax.random.key(7)
        base = enterprise().make_batch(key, 128, pmax=512)
        adv = adversarial(attack_fraction=0.0).make_batch(key, 128, pmax=512)
        assert jax.tree.all(jax.tree.map(
            lambda a, b: jnp.array_equal(a, b), base, adv))

    @settings(max_examples=6, deadline=None)
    @given(burst=st.integers(1, 63), seed=st.integers(0, 999))
    def test_attack_slots_are_supersets_across_fractions(self, burst, seed):
        """The permutation-rank coupling: raising the fraction only ADDS
        attack bursts — the monotone-drop property's foundation."""
        key = jax.random.key(seed)
        prev = None
        for frac in (0.2, 0.5, 0.9):
            wl = adversarial(attack_fraction=frac, burst=burst)
            pkts = wl.make_batch(key, 128, pmax=512)
            attacked = np.asarray(pkts.dst_ip) == VICTIM_IP
            assert np.asarray(pkts.payload_len)[attacked].max(initial=0) \
                <= ATTACK_SIZE - 42
            if prev is not None:
                assert np.all(attacked | ~prev), \
                    "lower-fraction attack slots must survive at higher frac"
            prev = attacked

    def test_churn_windows_overlap_by_half(self):
        # 64 draws over a 16-flow pool per window: every window visits
        # (essentially) its whole pool, so the half-window overlap and the
        # rotation are both deterministic at this density
        wl = churn(pool=16, rotate=64)
        pkts = wl.make_batch(jax.random.key(3), 256, pmax=512)
        ips = np.asarray(pkts.src_ip)
        windows = [set(ips[i:i + 64].tolist()) for i in range(0, 256, 64)]
        for w0, w1 in zip(windows, windows[1:]):
            assert w0 & w1, "adjacent churn windows must share flows"
            assert w0 != w1, "adjacent churn windows must also rotate flows"
        assert not (windows[0] & windows[2]), \
            "a flow lives across two windows, then never returns"


class TestDropRateMonotone:
    @settings(max_examples=3, deadline=None)
    @given(burst=st.sampled_from([4, 8, 16]), seed=st.integers(0, 99))
    def test_monotone_in_attack_fraction(self, burst, seed):
        specs = [_exhaust_spec(f, burst, seed=seed)
                 for f in (0.0, 0.5, 1.0)]
        rates = [_drop_rate(r) for r in S.run_matrix(specs)]
        assert rates == sorted(rates), (
            f"drop rate not monotone in attack load: {rates}")


class TestOccupancyBounded:
    @settings(max_examples=4, deadline=None)
    @given(capacity=st.sampled_from([32, 64]),
           frac=st.floats(0.3, 1.0),
           burst=st.sampled_from([4, 16]),
           seed=st.integers(0, 99))
    def test_occupancy_never_exceeds_capacity(self, capacity, frac, burst,
                                              seed):
        spec = _exhaust_spec(round(frac, 2), burst, seed=seed,
                             capacity=capacity)
        r = S.run_matrix([spec])[0]
        occ = np.asarray(r.per_pipe_occ_series)
        assert occ.max() <= capacity
        assert r.peak_occupancy <= capacity
        assert occ.min() >= 0


class TestEngineLoopThroughFaults:
    """The §10 headline invariant: one compiled program, bit-exact with
    the host loop through an arbitrarily placed fault event."""

    STEPS = pipe_trace_steps(128, 2, 32)

    @pytest.mark.parametrize("backend", ["ref", "pallas_interpret"])
    @pytest.mark.parametrize("recirc", [False, True])
    @settings(max_examples=2, deadline=None)
    @given(kind=st.sampled_from(["server", "lb"]),
           start=st.integers(0, STEPS - 1),
           pipe=st.integers(0, 1),
           drain=st.booleans(),
           bknd=st.integers(0, 7))
    def test_bitexact_across_random_fault(self, recirc, backend, kind,
                                          start, pipe, drain, bknd):
        dur = max(1, self.STEPS - start - 1)
        fault = FaultSpec(kind=kind, start=start, duration=dur,
                          pipe=pipe, backend=bknd, drain=drain)
        spec = S.ScenarioSpec(
            name=f"{kind}@{start}+{dur}", workload=("datacenter",),
            chain=("fw", "nat", "lb"), pipes=2, recirc=recirc,
            capacity=64, max_exp=2, packets=128, chunk=32, window=2,
            pmax=512, flows=64, fw_rules=8, explicit_drops=True,
            backend=backend, fault=fault)
        r = S.run_matrix([spec])[0]
        S.verify_oracle(r)  # counters + telemetry + NF counters

    def test_fault_actually_changes_behaviour(self):
        """A server fault over the whole trace must register fault_drops
        and differ from the healthy twin — guards against the masks
        silently not being threaded."""
        healthy = S.ScenarioSpec(
            name="healthy", workload=("datacenter",), chain=("fw", "nat"),
            pipes=2, capacity=64, max_exp=2, packets=128, chunk=32,
            window=2, pmax=512, explicit_drops=True)
        steps = pipe_trace_steps(128, 2, 32)
        faulted = dataclasses.replace(
            healthy, name="faulted",
            fault=FaultSpec(kind="server", start=0, duration=steps,
                            pipe=0, drain=True))
        rh, rf = S.run_matrix([healthy, faulted])  # one compile group
        assert rh.counters["fault_drops"] == 0
        assert rf.counters["fault_drops"] > 0
        assert rf.telemetry.merged_pkts < rh.telemetry.merged_pkts
        # drain semantics: no parked-slot leak even with pipe 0 dark
        assert int(np.asarray(rf.per_pipe_occ_series)[:, -1].sum()) == 0


class TestNatStaleRegression:
    """§10 stale-mapping rule: aged-out binding + in-flight packets with
    the old mapping -> counted + dropped, never silently translated."""

    def _batch(self, ips, ports):
        n = len(ips)
        p = make_udp_batch(jax.random.key(0), n, 200, pmax=256)
        return p.replace(src_ip=jnp.asarray(ips, jnp.int32),
                         src_port=jnp.asarray(ports, jnp.int32))

    def test_stale_hit_counts_drops_and_rebinds(self):
        nat = Nat(capacity=8, max_exp=1)
        st_ = nat.init_state()
        flow_a = (100, 1000)
        # 1) flow A binds
        st_, out, drop, _ = nat(st_, self._batch([flow_a[0]], [flow_a[1]]))
        assert not bool(drop[0])
        # 2) seven fillers take the seven free slots; the eighth finds the
        #    table exhausted -> CLOCK ages every slot to zero (keys stay)
        fillers = self._batch(list(range(200, 208)), [2000] * 8)
        st_, _, _, _ = nat(st_, fillers)
        assert int(jnp.sum(st_["exp"])) == 0, "CLOCK aging must have fired"
        # 3) flow A returns with its old (now stale) mapping in flight:
        #    must count + drop + tear the binding down, NOT translate
        st_, out, drop, _ = nat(st_, self._batch([flow_a[0]], [flow_a[1]]))
        assert bool(drop[0]), "stale mapping must not silently translate"
        assert not bool(out.alive[0])
        assert int(st_["stale_hits"]) == 1
        assert nat.state_counters(st_)["nat_stale_hits"] == 1
        assert not bool(jnp.any(st_["key_ip"] == flow_a[0])), \
            "stale binding must be torn down"
        # 4) the very next packet of flow A re-binds cleanly
        st_, out, drop, _ = nat(st_, self._batch([flow_a[0]], [flow_a[1]]))
        assert not bool(drop[0])
        assert int(out.src_port[0]) >= nat.base_port
        assert int(st_["stale_hits"]) == 1, "re-bind is not a stale hit"

    def test_fresh_flow_on_aged_slot_is_not_stale(self):
        """Aging alone is not a stale hit: a NEW flow re-using an aged
        slot is a clean insert."""
        nat = Nat(capacity=8, max_exp=1)
        st_ = nat.init_state()
        st_, _, _, _ = nat(st_, self._batch(list(range(50, 59)), [3000] * 9))
        st_, out, drop, _ = nat(st_, self._batch([999], [4000]))
        assert not bool(drop[0])
        assert int(st_["stale_hits"]) == 0


class TestDegradationGate:
    """compare.py enforces the artifact ``degradation`` block."""

    def _payload(self, ok):
        gate = dict(metric="drop_rate", op="<=", bound=0.5,
                    value=0.4 if ok else 0.9, ok=ok)
        return {"schema": 2, "bench": "adversarial", "rows": [],
                "summary": {},
                "degradation": {"ok": ok, "scenarios": {
                    "pt": {"metrics": {"drop_rate": gate["value"]},
                           "gates": [gate]}}}}

    def test_false_gate_fails(self):
        probs = compare.compare_degradation(self._payload(True),
                                            self._payload(False))
        assert any(p.startswith("INVARIANT") for p in probs)

    def test_ok_gates_pass(self):
        assert compare.compare_degradation(self._payload(True),
                                           self._payload(True)) == []

    def test_baseline_gate_may_not_disappear(self):
        cand = self._payload(True)
        del cand["degradation"]
        probs = compare.compare_degradation(self._payload(True), cand)
        assert any("MISSING" in p for p in probs)
        cand2 = self._payload(True)
        cand2["degradation"]["scenarios"]["pt"]["gates"] = []
        probs2 = compare.compare_degradation(self._payload(True), cand2)
        assert any("MISSING" in p and "drop_rate" in p for p in probs2)
