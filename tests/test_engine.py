"""Scanned multi-pipe engine: bit-exact equivalence with the seed chunk
loop, pipe steering invariants, and cross-pipe aggregation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packet import from_time_major, to_time_major, wire_bytes
from repro.core.park import ParkConfig
from repro.nf.chain import Chain
from repro.nf.firewall import Firewall
from repro.nf.macswap import MacSwap
from repro.nf.nat import Nat
from repro.switchsim import engine as E
from repro.switchsim.simulate import simulate, simulate_loop
from repro.traffic.generator import enterprise, fixed, flow_hash, steer_pipes


def _cat(batches):
    return jax.tree.map(lambda *xs: jnp.concatenate(xs), *batches)


def _assert_same_result(a, b):
    """Wire-level + accounting equality of two SimResults."""
    ga, la = wire_bytes(_cat(a.merged))
    gb, lb = wire_bytes(_cat(b.merged))
    np.testing.assert_array_equal(np.asarray(ga), np.asarray(gb))
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    sa, _ = wire_bytes(_cat(a.sent_to_server))
    sb, _ = wire_bytes(_cat(b.sent_to_server))
    np.testing.assert_array_equal(np.asarray(sa), np.asarray(sb))
    assert a.counters == b.counters
    assert a.srv_bytes == b.srv_bytes
    assert a.wire_bytes == b.wire_bytes
    assert a.ret_bytes == b.ret_bytes
    # per-link telemetry (DESIGN.md §7) is part of the oracle contract:
    # every byte AND packet count per link must match bit-exactly
    assert a.telemetry == b.telemetry
    np.testing.assert_array_equal(np.asarray(a.state.ptable),
                                  np.asarray(b.state.ptable))


class TestEngineEquivalence:
    """simulate() (scanned) must be bit-identical to simulate_loop() (seed)."""

    @pytest.mark.parametrize("wl,window", [
        (fixed(384), 1), (fixed(1492), 2), (enterprise(), 3),
    ])
    def test_matches_seed_loop(self, wl, window):
        pkts = wl.make_batch(jax.random.key(0), 256, pmax=1024)
        chain = Chain((MacSwap(),))
        cfg = ParkConfig(capacity=128, max_exp=2, pmax=1024)
        a = simulate(cfg, chain, pkts, window=window, chunk=64)
        b = simulate_loop(cfg, chain, pkts, window=window, chunk=64)
        _assert_same_result(a, b)

    def test_matches_with_drops_and_explicit_drops(self):
        pkts = enterprise().make_batch(jax.random.key(1), 256, pmax=1024)
        rules = tuple(int(ip) for ip in
                      np.unique(np.asarray(pkts.src_ip))[:64].tolist())
        chain = Chain((Firewall(rules=rules), Nat()))
        cfg = ParkConfig(capacity=64, max_exp=4, pmax=1024)
        for ed in (False, True):
            a = simulate(cfg, chain, pkts, window=2, chunk=64,
                         explicit_drops=ed)
            b = simulate_loop(cfg, chain, pkts, window=2, chunk=64,
                              explicit_drops=ed)
            _assert_same_result(a, b)

    def test_matches_under_premature_evictions(self):
        """The pathological regime (window*chunk > capacity) must agree too."""
        pkts = fixed(384).make_batch(jax.random.key(2), 512, pmax=1024)
        chain = Chain((MacSwap(),))
        cfg = ParkConfig(capacity=32, max_exp=1, pmax=1024)
        a = simulate(cfg, chain, pkts, window=4, chunk=64)
        b = simulate_loop(cfg, chain, pkts, window=4, chunk=64)
        assert a.counters["premature_evictions"] > 0
        _assert_same_result(a, b)

    def test_time_major_roundtrip(self):
        pkts = enterprise().make_batch(jax.random.key(3), 128, pmax=512)
        tm = to_time_major(pkts, 32)
        assert tm.payload.shape == (4, 32, 512)
        back = from_time_major(tm)
        np.testing.assert_array_equal(np.asarray(back.payload),
                                      np.asarray(pkts.payload))


class TestSteering:
    def test_flow_affinity_and_conservation(self):
        pkts = enterprise().make_batch(jax.random.key(4), 512, pmax=512)
        shards, stats = steer_pipes(pkts, 4, chunk=64)
        assert stats["overflow"] == 0
        assert sum(stats["per_pipe_arrivals"]) == 512
        # every alive packet appears exactly once across pipes
        assert int(jnp.sum(shards.alive)) == 512
        # flow affinity: a pipe's packets all hash to that pipe
        h = flow_hash(pkts) % 4
        for p in range(4):
            alive = np.asarray(shards.alive[p])
            sp = np.asarray(shards.src_port[p])[alive]
            si = np.asarray(shards.src_ip[p])[alive]
            orig = {(int(a), int(b)) for a, b in zip(
                np.asarray(pkts.src_ip)[np.asarray(h) == p],
                np.asarray(pkts.src_port)[np.asarray(h) == p])}
            assert {(int(a), int(b)) for a, b in zip(si, sp)} <= orig

    def test_single_pipe_is_identity_with_padding(self):
        pkts = fixed(384).make_batch(jax.random.key(5), 128, pmax=512)
        shards, stats = steer_pipes(pkts, 1, chunk=64)
        assert stats["per_pipe_arrivals"] == [128]
        np.testing.assert_array_equal(
            np.asarray(shards.payload[0, :128]), np.asarray(pkts.payload))
        assert not bool(shards.alive[0, 128:].any())

    def test_capacity_overflow_drops(self):
        pkts = fixed(384).make_batch(jax.random.key(6), 128, pmax=512)
        shards, stats = steer_pipes(pkts, 2, pipe_capacity=32, chunk=32)
        assert stats["overflow"] == 128 - int(jnp.sum(shards.alive))
        assert stats["overflow"] > 0


class TestTelemetry:
    """Per-link telemetry invariants (DESIGN.md §7)."""

    def test_internal_consistency_single_pipe(self):
        pkts = enterprise().make_batch(jax.random.key(20), 256, pmax=1024)
        chain = Chain((MacSwap(),))
        cfg = ParkConfig(capacity=256, max_exp=2, pmax=1024)
        res = E.run_engine(cfg, chain, to_time_major(pkts, 64), window=2)
        t = res.telemetry
        # derived views agree with the struct
        assert res.wire_bytes == t.wire_bytes
        assert res.srv_fwd_bytes == t.to_server_bytes
        assert res.srv_bytes == t.to_server_bytes + t.from_server_bytes
        assert res.ret_bytes == t.merged_bytes
        # MacSwap drops nothing: packet conservation per link
        assert t.wire_pkts == 256
        assert t.to_server_pkts == t.from_server_pkts == t.merged_pkts == 256
        # parking shrinks the forward link, merge restores full size
        assert t.to_server_bytes < t.wire_bytes
        assert t.merged_bytes == t.wire_bytes
        assert t.recirc_pkts == t.recirc_bytes == 0  # lane off

    def test_per_pipe_telemetry_sums_to_aggregate(self):
        from repro.switchsim.telemetry import sum_telemetry
        pkts = enterprise().make_batch(jax.random.key(21), 512, pmax=512)
        chain = Chain((MacSwap(),))
        cfg = ParkConfig(capacity=128, max_exp=2, pmax=512)
        shards, _ = steer_pipes(pkts, 4, chunk=64)
        traces = jax.tree.map(
            lambda a: a.reshape((4, a.shape[1] // 64, 64) + a.shape[2:]),
            shards)
        res = E.run_pipes(cfg, chain, traces, window=1)
        assert len(res.per_pipe_telemetry) == 4
        assert sum_telemetry(res.per_pipe_telemetry) == res.telemetry
        assert res.telemetry.wire_pkts == 512
        for p, tel in enumerate(res.per_pipe_telemetry):
            assert tel.srv_bytes == res.per_pipe_srv_bytes[p]
            assert tel.wire_bytes == res.per_pipe_wire_bytes[p]

    def test_chain_drops_show_in_return_direction(self):
        pkts = fixed(512).make_batch(jax.random.key(22), 256, pmax=1024)
        rules = tuple(int(ip) for ip in
                      np.unique(np.asarray(pkts.src_ip))[:64].tolist())
        chain = Chain((Firewall(rules=rules), Nat()))
        cfg = ParkConfig(capacity=512, max_exp=2, pmax=1024)
        res = E.run_engine(cfg, chain, to_time_major(pkts, 64), window=1)
        t = res.telemetry
        assert t.to_server_pkts == t.wire_pkts          # all offered forward
        assert t.from_server_pkts < t.to_server_pkts    # firewall dropped
        assert t.merged_pkts == t.from_server_pkts      # healthy merge
        assert t.merged_bytes == res.ret_bytes


class TestMultiPipe:
    def test_pipes_equal_independent_runs(self):
        """A vmapped P-pipe run must equal P separate single-pipe runs."""
        pkts = enterprise().make_batch(jax.random.key(7), 512, pmax=512)
        chain = Chain((MacSwap(),))
        cfg = ParkConfig(capacity=128, max_exp=2, pmax=512)
        shards, _ = steer_pipes(pkts, 2, chunk=64)
        traces = jax.tree.map(
            lambda a: a.reshape((2, a.shape[1] // 64, 64) + a.shape[2:]),
            shards)
        res = E.run_pipes(cfg, chain, traces, window=2)
        for p in range(2):
            solo = E.run_engine(
                cfg, chain, jax.tree.map(lambda a: a[p], traces), window=2)
            assert res.per_pipe_counters[p] == solo.counters
            assert res.per_pipe_srv_bytes[p] == solo.srv_bytes
            assert res.per_pipe_wire_bytes[p] == solo.wire_bytes
            got = jax.tree.map(lambda a: a[p], res.merged)
            gw, _ = wire_bytes(from_time_major(got))
            sw, _ = wire_bytes(from_time_major(solo.merged))
            np.testing.assert_array_equal(np.asarray(gw), np.asarray(sw))
        assert res.counters["splits"] == sum(
            c["splits"] for c in res.per_pipe_counters)
        assert res.srv_bytes == sum(res.per_pipe_srv_bytes)

    def test_goodput_gain_positive_for_parkable_traffic(self):
        pkts = fixed(512).make_batch(jax.random.key(8), 256, pmax=512)
        chain = Chain((MacSwap(),))
        cfg = ParkConfig(capacity=256, max_exp=2, pmax=512)
        shards, _ = steer_pipes(pkts, 2, chunk=64)
        traces = jax.tree.map(
            lambda a: a.reshape((2, a.shape[1] // 64, 64) + a.shape[2:]),
            shards)
        res = E.run_pipes(cfg, chain, traces, window=1)
        g = E.goodput_gain(res)
        # 512B packets park 160B and add 7B: saving = (160-7)/512 per hop
        assert abs(g["link_byte_saving"] - (160 - 7) / 512) < 0.01
        # MacSwap drops nothing: drop-aware and naive baselines coincide
        assert g["baseline_link_bytes"] == g["baseline_naive_link_bytes"]
