"""Training substrate: optimizer, loop convergence, checkpoint/restart,
gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import RunConfig, train
from repro.training import checkpoint as ckpt
from repro.training import compression
from repro.training.data import DataConfig, SyntheticStream
from repro.training.optimizer import (AdamWConfig, apply_updates,
                                      init_opt_state, schedule)


class TestOptimizer:
    def test_schedule_warmup_and_decay(self):
        cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
        lrs = [float(schedule(cfg, jnp.asarray(s))) for s in
               (0, 5, 10, 50, 100)]
        assert lrs[0] == 0.0
        assert abs(lrs[1] - 5e-4) < 1e-8
        assert abs(lrs[2] - 1e-3) < 1e-8
        assert lrs[3] < lrs[2]
        assert abs(lrs[4] - cfg.lr * cfg.min_lr_ratio) < 1e-8

    def test_adamw_reduces_quadratic(self):
        cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                          weight_decay=0.0)
        params = {"w": jnp.asarray([3.0, -2.0])}
        opt = init_opt_state(params)
        for _ in range(60):
            grads = {"w": 2 * params["w"]}
            params, opt, _ = apply_updates(cfg, params, opt, grads)
        assert float(jnp.abs(params["w"]).max()) < 0.3

    def test_grad_clip(self):
        cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
        params = {"w": jnp.ones((4,))}
        opt = init_opt_state(params)
        _, _, metrics = apply_updates(cfg, params, opt,
                                      {"w": jnp.full((4,), 100.0)})
        assert float(metrics["grad_norm"]) == pytest.approx(200.0)


class TestData:
    def test_deterministic_and_host_sharded(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8)
        s = SyntheticStream(cfg)
        a = s.batch_at(3)
        b = s.batch_at(3)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))
        c = s.batch_at(4)
        assert not np.array_equal(np.asarray(a["tokens"]),
                                  np.asarray(c["tokens"]))
        h0 = s.batch_at(3, host_index=0, host_count=2)
        assert h0["tokens"].shape == (4, 16)

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
        b = SyntheticStream(cfg).batch_at(0)
        # tokens[t+1] == labels[t] by construction
        np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                      np.asarray(b["labels"][:, :-1]))


class TestTrainLoop:
    def test_loss_decreases(self, tmp_path):
        out = train(RunConfig(arch="qwen2.5-3b", steps=30, seq_len=64,
                              global_batch=4, lr=3e-3, log_every=0))
        first = np.mean(out["losses"][:5])
        last = np.mean(out["losses"][-5:])
        assert last < first - 0.2, (first, last)

    def test_checkpoint_restart_bitexact(self, tmp_path):
        """Kill-and-resume must land on the same state as an uninterrupted
        run (fault-tolerance contract)."""
        d1 = str(tmp_path / "a")
        d2 = str(tmp_path / "b")
        full = train(RunConfig(arch="qwen2.5-3b", steps=20, seq_len=32,
                               global_batch=2, ckpt_dir=d1, ckpt_every=10,
                               log_every=0))
        # interrupted run: same 20-step schedule, crash after step 10,
        # then a fresh process-equivalent resume
        train(RunConfig(arch="qwen2.5-3b", steps=20, seq_len=32,
                        global_batch=2, ckpt_dir=d2, ckpt_every=10,
                        log_every=0, stop_after=10))
        resumed = train(RunConfig(arch="qwen2.5-3b", steps=20, seq_len=32,
                                  global_batch=2, ckpt_dir=d2, ckpt_every=10,
                                  log_every=0))
        for a, b in zip(jax.tree.leaves(full["state"]["params"]),
                        jax.tree.leaves(resumed["state"]["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_step_discovery(self, tmp_path):
        d = str(tmp_path / "c")
        assert ckpt.latest_step(d) is None
        tree = {"x": jnp.arange(4)}
        ckpt.save(d, 5, tree)
        ckpt.save(d, 10, tree)
        assert ckpt.latest_step(d) == 10
        back = ckpt.restore(d, 10, jax.eval_shape(lambda: tree))
        np.testing.assert_array_equal(np.asarray(back["x"]), np.arange(4))


class TestCompression:
    def test_roundtrip_bounded_error(self):
        g = {"w": jax.random.normal(jax.random.key(0), (128,))}
        err = compression.init_error_state(g)
        out, err = compression.compress_decompress(g, err)
        scale = float(jnp.max(jnp.abs(g["w"]))) / 127
        assert float(jnp.max(jnp.abs(out["w"] - g["w"]))) <= scale * 0.51

    def test_error_feedback_accumulates(self):
        """Constant gradients: the error-feedback mean converges to the true
        gradient (no bias)."""
        g = {"w": jnp.full((16,), 0.01) + jnp.arange(16) * 1e-4}
        err = compression.init_error_state(g)
        total = jnp.zeros((16,))
        n = 50
        for _ in range(n):
            out, err = compression.compress_decompress(g, err)
            total = total + out["w"]
        np.testing.assert_allclose(np.asarray(total / n), np.asarray(g["w"]),
                                   rtol=0.02, atol=1e-5)

    def test_training_with_compression_converges(self):
        out = train(RunConfig(arch="qwen2.5-3b", steps=25, seq_len=64,
                              global_batch=4, lr=3e-3, compress_grads=True,
                              log_every=0))
        assert np.mean(out["losses"][-5:]) < np.mean(out["losses"][:5]) - 0.15
