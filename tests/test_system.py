"""End-to-end behaviour tests for the whole system (deliverable c)."""
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_quickstart_example_runs():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable,
                        os.path.join(REPO, "examples", "quickstart.py")],
                       capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "functional equivalence: OK" in r.stdout


def test_parked_decode_example_runs():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable,
                        os.path.join(REPO, "examples", "parked_decode.py")],
                       capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "goodput gain" in r.stdout


def test_benchmark_figures_importable_and_fig7_matches_paper():
    sys.path.insert(0, REPO)
    from benchmarks.figures import fig7_goodput_latency_10ge
    rows = fig7_goodput_latency_10ge()
    gain = [v for n, v, d in rows if n == "fig7/peak_gain_pct"][0]
    # paper: +13% goodput on the FW->NAT->LB 10GE enterprise workload
    assert 10.0 < gain < 18.0, gain


def test_dryrun_collective_parser():
    from repro.launch.dryrun import collective_stats
    hlo = """
  %all-reduce = f32[8,128]{1,0} all-reduce(%dot), replica_groups=[2,4]<=[8], to_apply=%add
  %ag = bf16[16,64]{1,0} all-gather(%p), replica_groups={{0,1,2,3}}, dimensions={0}
  %rs = f32[4]{0} reduce-scatter(%x), replica_groups={{0,1},{2,3}}, to_apply=%add
"""
    s = collective_stats(hlo)
    assert s["all-reduce"]["count"] == 1
    assert s["all-reduce"]["bytes"] == 8 * 128 * 4 * 2 * 3 / 4
    assert s["all-gather"]["bytes"] == 16 * 64 * 2 * 3 / 4
    assert s["reduce-scatter"]["bytes"] == 4 * 4 * 1
    assert s["total_bytes"] > 0


def test_accounting_probe_plan_covers_all_archs():
    from repro import configs
    from repro.configs.shapes import SHAPES
    from repro.launch.accounting import probe_plan
    for arch in configs.names():
        cfg = configs.get(arch)
        for shape in SHAPES.values():
            probes, combine = probe_plan(cfg, shape)
            assert len(probes) >= 2
            # combine of identical costs must be the identity at layer=1..
            fake = {p.name: {"flops": 100.0} for p in probes}
            out = combine(fake)
            assert out["flops"] >= 100.0
