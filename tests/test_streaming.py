"""Streaming steady-state engine tests (DESIGN.md §13).

Covers the four §13 contracts:

  * **segment replay ≡ materialized** — streaming any prefix segment-by-
    segment with a donated carry is bit-identical (counters, full per-link
    telemetry, NF counters, peak occupancy) to the materialized engine
    over the same concatenated chunks, in both recirculation modes, on the
    ref and pallas_interpret backends, and for any segmentation of the
    same trace;
  * **constant memory** — the driver never asks the source for more than
    one segment of packets and retains no per-step traffic in its result;
  * **reservoir quantiles** — with a reservoir large enough to hold every
    sample the p50/p99/p999 equal the exact offline quantiles recomputed
    from the materialized merged output via the same integer-ns sojourn
    model; an undersized reservoir stays near the exact tail;
  * **synthetic-source determinism** — chunk ``t`` is a pure function of
    ``(seed, t)``: re-materialization is bit-identical, segments are pure
    slices, and the diurnal modulator's offered counts (and all-zero dead
    tails) are exactly reproducible.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.park import ParkConfig
from repro.nf.chain import Chain
from repro.nf.nat import Nat
from repro.switchsim.engine import recirc_slots, run_engine
from repro.switchsim.stream import (replay_oracle, run_stream, sojourn_ns,
                                    step_ns_for)
from repro.traffic.stream import (DiurnalLoad, FlowPool, MaterializedSource,
                                  SyntheticSource, as_source)

CHAIN = Chain((Nat(),))
WINDOW = 2


def make_source(steps=24, chunk=16, pmax=256, seed=5):
    return SyntheticSource(steps=steps, chunk=chunk, pmax=pmax, seed=seed,
                           flows=5000, load=DiurnalLoad(period=16))


def make_cfg(recirc: bool) -> ParkConfig:
    return ParkConfig(capacity=64, max_exp=2, pmax=256,
                      recirculation=recirc, recirc_frac=0.25)


def _offline_samples(cfg, merged, window):
    """The exact offline sojourn distribution: the same integer-ns model
    applied to the materialized engine's merged output."""
    lane = recirc_slots(cfg, merged.alive.shape[1])
    step_ns = step_ns_for(window)
    lane_rows = jnp.arange(merged.alive.shape[1]) < lane
    ns = sojourn_ns(merged.pkt_len(), lane_rows[None, :], window, step_ns)
    return np.asarray(ns)[np.asarray(merged.alive)]


class TestReplayOracle:
    @pytest.mark.parametrize("recirc", [False, True])
    @pytest.mark.parametrize("backend", ["ref", "pallas_interpret"])
    def test_stream_equals_materialized(self, recirc, backend):
        cfg = make_cfg(recirc)
        rep = replay_oracle(cfg, CHAIN, make_source(), window=WINDOW,
                            segment_len=6, segments=4, backend=backend)
        assert rep["steps"] == 24
        assert rep["packets"] == 24 * 16

    @pytest.mark.parametrize("recirc", [False, True])
    def test_full_stream_equals_run_engine(self, recirc):
        cfg = make_cfg(recirc)
        src = make_source()
        s = run_stream(cfg, CHAIN, src, window=WINDOW, segment_len=8)
        m = run_engine(cfg, CHAIN, src.materialize(), window=WINDOW)
        assert s.counters == m.counters
        assert s.telemetry == m.telemetry
        assert s.nf_counters == m.nf_counters
        assert s.peak_occupancy == m.peak_occupancy

    def test_segmentation_invariance(self):
        """Any segmentation of the same trace produces the same result —
        including the reservoir (insertion is per step, in step order,
        regardless of where segment boundaries fall)."""
        cfg = make_cfg(True)
        src = make_source()
        runs = [run_stream(cfg, CHAIN, src, window=WINDOW, segment_len=n)
                for n in (4, 6, 24)]
        ref = runs[0]
        for other in runs[1:]:
            assert other.counters == ref.counters
            assert other.telemetry == ref.telemetry
            assert other.latency == ref.latency
            assert other.peak_occupancy == ref.peak_occupancy

    def test_materialized_entry_points_accept_sources(self):
        """run_engine takes a TraceSource directly (the API unification:
        arrays are just the trivial MaterializedSource)."""
        cfg = make_cfg(False)
        src = make_source()
        a = run_engine(cfg, CHAIN, src, window=WINDOW)
        b = run_engine(cfg, CHAIN, src.materialize(), window=WINDOW)
        assert a.counters == b.counters
        assert a.telemetry == b.telemetry


class TestConstantMemory:
    def test_driver_pulls_one_segment_at_a_time(self, monkeypatch):
        src = make_source(steps=40)
        calls = []
        orig = SyntheticSource.segment

        def spy(self, start, count):
            calls.append((start, count))
            return orig(self, start, count)

        monkeypatch.setattr(SyntheticSource, "segment", spy)
        res = run_stream(make_cfg(True), CHAIN, src, window=WINDOW,
                         segment_len=8)
        # one 1-step probe for the chunk template, then exactly the
        # contiguous 8-step segments, never more, never materialize()
        assert calls[0] == (0, 1)
        assert calls[1:] == [(s, 8) for s in range(0, 40, 8)]
        assert max(c for _, c in calls) <= 8
        assert res.steps == 40

    def test_result_retains_no_per_step_traffic(self):
        res = run_stream(make_cfg(False), CHAIN, make_source(),
                         window=WINDOW, segment_len=8)
        assert not hasattr(res, "merged")
        assert not hasattr(res, "sent")
        assert not hasattr(res, "occ_series")
        # occupancy survives only as O(segments) summaries
        assert all(set(s) == {"start", "steps", "min", "mean", "max",
                              "last"} for s in res.occ_segments)

    def test_overlong_segment_rejected(self):
        # int32 telemetry guard: segment byte sums must stay below 2^31
        src = SyntheticSource(steps=2**20, chunk=1024, pmax=2048, seed=0)
        with pytest.raises(ValueError, match="int32 telemetry"):
            run_stream(make_cfg(False), CHAIN, src, window=WINDOW,
                       segment_len=2**20)


class TestReservoir:
    def test_quantiles_exact_when_reservoir_holds_all(self):
        cfg = make_cfg(True)
        src = make_source()
        res = run_stream(cfg, CHAIN, src, window=WINDOW, segment_len=8,
                         reservoir=4096)
        m = run_engine(cfg, CHAIN, src.materialize(), window=WINDOW)
        samples = _offline_samples(cfg, m.merged, WINDOW)
        assert res.latency["samples"] == samples.size
        assert samples.size < 4096  # the premise: nothing was evicted
        for name, q in (("p50_us", 0.50), ("p99_us", 0.99),
                        ("p999_us", 0.999)):
            exact = float(np.quantile(np.sort(samples), q,
                                      method="nearest")) / 1e3
            assert res.latency[name] == exact, (name, res.latency, exact)

    def test_small_reservoir_tracks_exact_tail(self):
        cfg = make_cfg(True)
        src = make_source(steps=48, chunk=32)
        res = run_stream(cfg, CHAIN, src, window=WINDOW, segment_len=8,
                         reservoir=96)
        m = run_engine(cfg, CHAIN, src.materialize(), window=WINDOW)
        samples = _offline_samples(cfg, m.merged, WINDOW)
        assert res.latency["samples"] == samples.size > 96
        exact_p99 = float(np.quantile(samples, 0.99, method="nearest")) / 1e3
        # deterministic subsample (fixed splitmix coin): the p99 estimate
        # must land near the exact tail — the O(sqrt(q(1-q)/K)) rank-error
        # band, generously widened for the tiny K
        assert res.latency["p99_us"] == pytest.approx(exact_p99, rel=0.20)

    def test_sojourn_model_integer_ns(self):
        # window steps at 15 us each (30 us dwell / window=2) + 0.8 ns/B
        assert step_ns_for(2) == 15_000
        assert int(sojourn_ns(1000, 0, 2, 15_000)) == 30_800
        assert int(sojourn_ns(1000, 1, 2, 15_000)) == 45_800


class TestSyntheticSource:
    def test_rematerialization_bit_identical(self):
        a = make_source().materialize()
        b = make_source().materialize()
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_segments_are_pure_slices(self):
        src = make_source()
        whole = src.materialize()
        part = src.segment(6, 6)
        for x, y in zip(jax.tree.leaves(part), jax.tree.leaves(whole)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y)[6:12])

    def test_diurnal_offered_counts_and_dead_tails(self):
        src = make_source(steps=16)
        trace = src.materialize()
        alive = np.asarray(trace.src_ip[..., 0] if trace.src_ip.ndim == 3
                           else trace.alive)
        for t in range(16):
            offered = int(src.load.offered(jnp.int32(t), src.chunk))
            assert int(np.asarray(trace.alive)[t].sum()) == offered
            # dead tail rows are fully zero in EVERY field, not just masked
            for leaf in jax.tree.leaves(
                    jax.tree.map(lambda a: a[t, offered:], trace)):
                assert not np.asarray(leaf).any()

    def test_seed_changes_trace(self):
        a = make_source(seed=5).materialize()
        b = make_source(seed=6).materialize()
        assert any(
            not np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    def test_flow_pool_identities_deterministic(self):
        pool = FlowPool(1_000_000, seed=3)
        idx = jnp.arange(4096, dtype=jnp.int32)
        ip1, port1 = pool.identity(idx)
        ip2, port2 = pool.identity(idx)
        np.testing.assert_array_equal(np.asarray(ip1), np.asarray(ip2))
        np.testing.assert_array_equal(np.asarray(port1), np.asarray(port2))
        assert np.asarray(ip1).min() >= 1
        assert 1024 <= np.asarray(port1).min()
        assert np.asarray(port1).max() < 1024 + 2**15
        # millions-of-flows sizing: distinct indices rarely collide
        assert len(np.unique(np.asarray(ip1))) > 4000

    def test_as_source_spellings(self):
        src = make_source()
        assert as_source(src) is src
        trace = src.materialize()
        ms = as_source(trace)
        assert isinstance(ms, MaterializedSource)
        assert ms.steps == src.steps and ms.chunk == src.chunk
        with pytest.raises(TypeError, match="TraceSource or PacketBatch"):
            as_source([1, 2, 3])

    def test_prefix_replace_is_pure(self):
        src = make_source()
        short = dataclasses.replace(src, steps=8)
        a = short.materialize()
        b = src.segment(0, 8)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
