"""Distributed correctness on a forced-host 8-device mesh.

Each test runs in a SUBPROCESS because the device count must be fixed before
jax initializes (the main pytest process keeps 1 device for the smoke tests).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.configs.reduced import reduced
from repro.distributed.sharding import Rules
from repro.models.lm import LM
from repro.training.train_step import TrainConfig, init_train_state, train_step
from repro.training.optimizer import AdamWConfig
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = reduced(configs.get("minitron-8b"))
lm = LM(cfg)
tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10))
state = init_train_state(lm, jax.random.key(0))
batch = {
  "tokens": jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size, dtype=jnp.int32),
  "labels": jax.random.randint(jax.random.key(2), (4, 32), 0, cfg.vocab_size, dtype=jnp.int32),
}
"""


def test_sharded_train_step_matches_single_device():
    run_sub(PRELUDE + """
# single-device reference
ref_state, ref_metrics = train_step(lm, tcfg, state, batch)

with mesh:
    rules = Rules(cfg, mesh)
    sspec = rules.to_shardings(rules.state_spec(state))
    bspec = rules.to_shardings(rules.batch_spec(batch))
    st = jax.device_put(state, sspec)
    bt = jax.device_put(batch, bspec)
    fn = jax.jit(lambda s, b: train_step(lm, tcfg, s, b,
                                         shard=rules.act_shard()),
                 in_shardings=(sspec, bspec), out_shardings=(sspec, None))
    new_state, metrics = fn(st, bt)

assert abs(float(metrics["loss"]) - float(ref_metrics["loss"])) < 2e-2, (
    float(metrics["loss"]), float(ref_metrics["loss"]))
for a, b in zip(jax.tree.leaves(ref_state["params"]),
                jax.tree.leaves(new_state["params"])):
    d = np.max(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)))
    assert d < 0.05, d
print("sharded == single OK")
""")


def test_sharded_decode_matches_single_device():
    run_sub(PRELUDE + """
params = state["params"]
toks = batch["tokens"]
logits_ref, cache_ref = lm.prefill(params, {"tokens": toks}, cache_len=40)
out_ref, _ = lm.decode_step(params, cache_ref,
                            jnp.argmax(logits_ref, -1).astype(jnp.int32),
                            jnp.full((4,), 32, jnp.int32))
with mesh:
    rules = Rules(cfg, mesh)
    pspec = rules.to_shardings(rules.param_specs(params))
    pt = jax.device_put(params, pspec)
    logits_s, cache_s = jax.jit(
        lambda p, b: lm.prefill(p, b, cache_len=40,
                                shard=rules.act_shard()))(pt, {"tokens": toks})
    out_s, _ = jax.jit(
        lambda p, c, t, i: lm.decode_step(p, c, t, i,
                                          shard=rules.act_shard()))(
        pt, cache_s, jnp.argmax(logits_s, -1).astype(jnp.int32),
        jnp.full((4,), 32, jnp.int32))
d = np.max(np.abs(np.asarray(out_ref, np.float32)
                  - np.asarray(out_s, np.float32)))
assert d < 0.06, d
print("decode sharded OK", d)
""")


def test_checkpoint_reshard_elastic():
    """Save under a (2,4) mesh, restore under (4,2) — elastic rescale."""
    run_sub(PRELUDE + """
import tempfile, os
from repro.training import checkpoint as ckpt
with mesh:
    rules = Rules(cfg, mesh)
    sspec = rules.to_shardings(rules.state_spec(state))
    st = jax.device_put(state, sspec)
d = tempfile.mkdtemp()
ckpt.save(d, 1, st)

mesh2 = jax.make_mesh((4, 2), ("data", "model"))
with mesh2:
    rules2 = Rules(cfg, mesh2)
    template = jax.eval_shape(lambda: init_train_state(lm, jax.random.key(0)))
    sspec2 = rules2.to_shardings(rules2.state_spec(template))
    restored = ckpt.restore(d, 1, template, sspec2)
for a, b in zip(jax.tree.leaves(state["params"]),
                jax.tree.leaves(restored["params"])):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("elastic reshard OK")
""")


def test_quantized_psum_shard_map():
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.training.compression import quantized_psum
mesh = jax.make_mesh((8,), ("data",))
x = jax.random.normal(jax.random.key(0), (8, 64))

@partial(shard_map, mesh=mesh, in_specs=P("data", None), out_specs=P("data", None))
def f(xs):
    return quantized_psum(xs, "data")[None] * jnp.ones((xs.shape[0], 1))

got = f(x)[0]
want = x.sum(0)
err = np.max(np.abs(np.asarray(got) - np.asarray(want)))
scale = np.max(np.abs(np.asarray(x))) / 127 * 8
assert err <= scale + 1e-5, (err, scale)
print("quantized psum OK", err)
""")


def test_pipeline_parallel_matches_sequential():
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_apply, sequential_apply
mesh = jax.make_mesh((4, 2), ("pod", "model"))
# toy 4-stage MLP pipeline
k = jax.random.key(0)
ws = jax.random.normal(k, (4, 16, 16)) * 0.3
x = jax.random.normal(jax.random.key(1), (8, 4, 16))  # (microbatches, mb, d)

def stage(w, x):
    return jnp.tanh(x @ w)

want = sequential_apply(stage, ws, x)
with mesh:
    got = pipeline_apply(stage, ws, x, mesh, stage_axis="pod")
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           rtol=2e-5, atol=2e-5)
print("pipeline OK")
""")
