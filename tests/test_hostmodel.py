"""NF-server host model (DESIGN.md §7): PCIe/TLP arithmetic, NIC/DMA byte
accounting fed by engine telemetry, per-server cycle budget, and the
multi-server table slicing against the resources placement model."""
import jax
import pytest

from repro.core.packet import HDR_BYTES, PP_HDR_BYTES, to_time_major
from repro.core.park import ParkConfig
from repro.hostmodel import (HostModel, PcieLink, baseline_dma, parked_dma,
                             pcie_reduction, per_server_capacity,
                             server_bound_pps, server_report,
                             servers_per_pipe)
from repro.nf.chain import Chain
from repro.nf.macswap import MacSwap
from repro.switchsim import engine as E
from repro.switchsim import resources
from repro.switchsim.perfmodel import ServerModel, digest, evaluate_host
from repro.switchsim.telemetry import LinkTelemetry, sum_telemetry
from repro.traffic.generator import enterprise, fixed


class TestPcieLink:
    """TLP + descriptor overhead arithmetic (pcie-bench style)."""

    def test_effective_rate_gen3_x8(self):
        link = PcieLink(gen=3, lanes=8)
        assert link.raw_gbps == pytest.approx(64.0)
        # 128b/130b encoding: ~63 Gbps byte-rate ceiling per direction
        assert link.effective_gbps == pytest.approx(64.0 * 128 / 130)

    def test_generation_scaling(self):
        # Gen4 doubles Gen3; Gen2 pays 8b/10b
        assert PcieLink(gen=4, lanes=8).raw_gbps == \
            2 * PcieLink(gen=3, lanes=8).raw_gbps
        assert PcieLink(gen=2, lanes=8).effective_gbps == \
            pytest.approx(5.0 * 8 * 0.8)

    def test_tlp_count(self):
        link = PcieLink(max_payload=256)
        assert link.data_tlps(0) == 0
        assert link.data_tlps(1) == 1
        assert link.data_tlps(103) == 1     # PayloadPark header packet
        assert link.data_tlps(256) == 1
        assert link.data_tlps(257) == 2
        assert link.data_tlps(1492) == 6

    def test_bus_bytes_per_packet_exact(self):
        link = PcieLink(max_payload=256, tlp_overhead=24, desc_bytes=16)
        # one data TLP + descriptor fetch + writeback (each 16B + 24B hdr)
        assert link.dma_bus_bytes(103) == 103 + 24 + 2 * (16 + 24)
        # 1492B = 6 TLPs
        assert link.dma_bus_bytes(1492) == 1492 + 6 * 24 + 80
        assert link.dma_bus_bytes(0) == 0

    def test_aggregate_matches_per_packet_for_fixed_sizes(self):
        link = PcieLink()
        n = 37
        assert link.bus_bytes(n, n * 512) == n * link.dma_bus_bytes(512)

    def test_small_packets_cannot_sustain_40g(self):
        """The §6.2.2 observation falls out: at ~103B the bus moves ~2x
        the packet's bytes, well under 40G data throughput."""
        link = PcieLink(gen=3, lanes=8)
        assert link.data_gbps_at(103) < 40.0 < link.data_gbps_at(1492)

    @pytest.mark.parametrize("kw", [
        dict(gen=0), dict(gen=6), dict(lanes=3), dict(max_payload=32),
        dict(tlp_overhead=-1),
    ])
    def test_bad_link_raises(self, kw):
        with pytest.raises(ValueError):
            PcieLink(**kw)


class TestDmaAccounting:
    """Header-only vs full-packet DMA bytes, from real engine telemetry."""

    def _run(self, size, n=256, capacity=512):
        pkts = fixed(size).make_batch(jax.random.key(0), n, pmax=2048)
        cfg = ParkConfig(capacity=capacity, max_exp=2, pmax=2048)
        return E.run_engine(cfg, Chain((MacSwap(),)),
                            to_time_major(pkts, 64), window=1), n

    def test_parked_rx_is_header_only(self):
        res, n = self._run(512)
        link = PcieLink()
        dma = parked_dma(link, res.telemetry)
        # every parked packet DMAs 42B hdr + 7B PP + (payload - 160) tail
        expect = n * (512 - 160 + PP_HDR_BYTES)
        assert dma.rx_bytes == expect
        assert dma.tx_bytes == expect          # MacSwap returns them all
        assert dma.rx_pkts == dma.tx_pkts == n

    def test_baseline_rx_is_full_packet(self):
        res, n = self._run(512)
        dma = baseline_dma(PcieLink(), res.telemetry)
        assert dma.rx_bytes == n * 512 == res.telemetry.wire_bytes
        assert dma.tx_bytes == n * 512         # all survive, full size

    def test_unsplittable_traffic_pays_pp_header(self):
        res, n = self._run(150)  # payload 108 < 160: ENB=0, +7B each way
        tel = res.telemetry
        assert tel.to_server_bytes == n * (150 + PP_HDR_BYTES)
        assert pcie_reduction(PcieLink(), tel) < 0  # parking costs here

    def test_reduction_in_paper_band_for_splittable_sizes(self):
        link = PcieLink()
        last = 1.0
        for size in (256, 384, 512, 1024, 1492):
            res, _ = self._run(size)
            red = pcie_reduction(link, res.telemetry)
            assert 0.02 <= red <= 0.58, (size, red)
            assert red <= last  # monotone: bigger packets park less share
            last = red

    def test_reduction_below_raw_byte_saving(self):
        """Per-packet DMA overheads do not shrink with parking, so the
        bus-load reduction is strictly below the link-byte saving."""
        res, _ = self._run(256)
        tel = res.telemetry
        byte_saving = 1 - (tel.to_server_bytes + tel.from_server_bytes) / \
            (tel.wire_bytes + tel.merged_bytes)
        assert pcie_reduction(PcieLink(), tel) < byte_saving


class TestServerBudget:
    def test_data_movement_bounds_pps(self):
        """More DMA'd bytes per packet -> fewer pps from the same cores."""
        hm = HostModel()
        small = server_bound_pps(hm, [50.0], 103, 103)
        large = server_bound_pps(hm, [50.0], 1492, 1492)
        assert small.pps > large.pps
        assert small.cycles_per_pkt < large.cycles_per_pkt

    def test_cycles_include_all_three_terms(self):
        hm = HostModel(overhead_cycles=60.0, cycles_per_byte=0.2)
        b = server_bound_pps(hm, [300.0], 100, 100)
        assert b.cycles_per_pkt == pytest.approx(300 + 60 + 0.2 * 200)

    def test_heavy_nf_is_cpu_bound(self):
        b = server_bound_pps(HostModel(), [570.0], 103, 103)
        assert b.bottleneck == "cpu"

    def test_byte_heavy_traffic_is_pcie_bound(self):
        hm = HostModel(cpu_ghz=100.0, dma_txn_mpps=1e6)  # remove other caps
        b = server_bound_pps(hm, [50.0], 1492, 103)
        assert b.bottleneck == "pcie_rx"
        assert b.caps["pcie_rx"] < b.caps["pcie_tx"]

    def test_server_report_gain_direction(self):
        pkts = fixed(512).make_batch(jax.random.key(1), 256, pmax=2048)
        cfg = ParkConfig(capacity=512, max_exp=2, pmax=2048)
        res = E.run_engine(cfg, Chain((MacSwap(),)),
                           to_time_major(pkts, 64), window=1)
        rep = server_report(HostModel(), res.telemetry, [50.0])
        assert rep["server_pps_gain"] > 0
        assert rep["pcie_reduction"] == \
            pytest.approx(pcie_reduction(HostModel().link, res.telemetry))


class TestServerSlicing:
    """1..8 server table slicing must agree with resources._placement."""

    def test_servers_per_pipe(self):
        assert [servers_per_pipe(n) for n in range(1, 9)] == \
            [1, 1, 1, 1, 2, 2, 2, 2]
        with pytest.raises(ValueError):
            servers_per_pipe(0)

    @pytest.mark.parametrize("n_servers", list(range(1, 9)))
    def test_slice_fits_placement_budget(self, n_servers):
        """The per-server capacity is the largest whose *placed* SRAM cost
        (whole 16KB blocks, replicated per server slice) fits the budget."""
        cfg = ParkConfig()
        frac = 0.40
        cap = per_server_capacity(frac, cfg, n_servers)
        assert cap > 0
        spp = servers_per_pipe(n_servers)
        budget = frac * resources.PIPE_SRAM_BYTES
        cost = sum(resources._placement(cap, cfg.banks, spp)) \
            * resources.SRAM_BLOCK_BYTES
        over = sum(resources._placement(cap + 1, cfg.banks, spp)) \
            * resources.SRAM_BLOCK_BYTES
        assert cost <= budget < over

    def test_more_servers_never_more_slots(self):
        cfg = ParkConfig()
        caps = [per_server_capacity(0.40, cfg, n) for n in range(1, 9)]
        assert all(a >= b for a, b in zip(caps, caps[1:]))


class TestTelemetryStruct:
    def test_sum_and_add(self):
        a = LinkTelemetry(wire_pkts=1, wire_bytes=100, to_server_pkts=1,
                          to_server_bytes=60, from_server_pkts=1,
                          from_server_bytes=60, merged_pkts=1,
                          merged_bytes=100)
        total = sum_telemetry([a, a, a])
        assert total.wire_bytes == 300
        assert total.srv_bytes == 360
        assert (a + a).wire_pkts == 2
        assert sum_telemetry([]) == LinkTelemetry()


class TestPerfmodelBridge:
    def test_parking_lowers_predicted_pcie_util(self):
        m = ServerModel(link_gbps=40.0)
        chain = [46.0, 80.0]
        d_base = digest([512], [1.0], 160, 160, False)
        d_park = digest([512], [1.0], 160, 160, True)
        b = evaluate_host(m, d_base, chain, send_gbps=10.0)
        p = evaluate_host(m, d_park, chain, send_gbps=10.0)
        assert p.pcie_util < b.pcie_util
        assert p.pcie_rx_gbps < b.pcie_rx_gbps
        assert b.server_pps_cap > 0 and p.server_pps_cap > b.server_pps_cap

    def test_host_cap_clamps_delivered_pps(self):
        """A deliberately weak host bounds pps below the link model."""
        from repro.hostmodel import PcieLink as PL
        weak = HostModel(cpu_ghz=0.1)
        m = ServerModel(link_gbps=40.0)
        d = digest([512], [1.0], 160, 160, False)
        hop = evaluate_host(m, d, [570.0], send_gbps=40.0, host=weak)
        assert hop.server_bottleneck == "cpu"
        assert hop.server_pps_cap < hop.op.pps
        assert isinstance(weak.link, PL)


class TestEnterpriseWorkload:
    def test_splittable_share(self):
        wl = enterprise()
        s = wl.splittable_share()
        # 70% of packets are splittable, each parking 160B of ~880B mean
        assert s == pytest.approx(0.70 * 160 / wl.mean_pkt_bytes)
        assert fixed(256).splittable_share() == pytest.approx(160 / 256)
        assert fixed(190).splittable_share() == 0.0
