"""Recirculation subsystem (paper §6.2.5, DESIGN.md §6): core second-pass
ops, the engine's recirculation lane + port budget, and the accounting
fixes that ride along (drop-aware goodput baseline, merge-width clamp,
config validation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import counters as C
from repro.core.packet import (HDR_BYTES, make_udp_batch, to_time_major,
                               wire_bytes)
from repro.core.park import (PARK_BYTES_BASE, PARK_BYTES_RECIRC, ParkConfig,
                             init_state, merge, recirc, split)
from repro.nf.chain import Chain
from repro.nf.firewall import Firewall
from repro.nf.macswap import MacSwap
from repro.nf.nat import Nat
from repro.switchsim import engine as E
from repro.switchsim.simulate import simulate, simulate_loop
from repro.traffic.generator import enterprise, fixed


def mk(key, n, size, pmax=1024):
    return make_udp_batch(jax.random.key(key), n, size, pmax=pmax)


def _cat(batches):
    return jax.tree.map(lambda *xs: jnp.concatenate(xs), *batches)


def _assert_same_result(a, b):
    """Wire-level + accounting equality of two SimResults."""
    ga, la = wire_bytes(_cat(a.merged))
    gb, lb = wire_bytes(_cat(b.merged))
    np.testing.assert_array_equal(np.asarray(ga), np.asarray(gb))
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    sa, _ = wire_bytes(_cat(a.sent_to_server))
    sb, _ = wire_bytes(_cat(b.sent_to_server))
    np.testing.assert_array_equal(np.asarray(sa), np.asarray(sb))
    assert a.counters == b.counters
    assert a.srv_bytes == b.srv_bytes
    assert a.wire_bytes == b.wire_bytes
    assert a.ret_bytes == b.ret_bytes
    # per-link telemetry (DESIGN.md §7) must match bit-exactly — including
    # the recirculation-port tallies the lane adds in recirc mode
    assert a.telemetry == b.telemetry


class TestSecondPass:
    """core.park.recirc_fn: continuation + retry semantics."""

    def test_two_passes_park_352(self):
        cfg = ParkConfig(capacity=64, max_exp=2, pmax=1024,
                         recirculation=True)
        assert cfg.park_bytes == PARK_BYTES_RECIRC == 352
        assert cfg.pass_bytes == PARK_BYTES_BASE == 160
        st = init_state(cfg)
        pkts = mk(0, 8, 500)  # payload 458
        st, sent = split(cfg, st, pkts)
        # first pass parks exactly pass_bytes
        assert jnp.all(sent.payload_len == pkts.payload_len - 160)
        st, rec = recirc(cfg, st, sent)
        assert jnp.all(rec.payload_len == pkts.payload_len - 352)
        # tag unchanged across the second pass
        np.testing.assert_array_equal(np.asarray(rec.pp_ti),
                                      np.asarray(sent.pp_ti))
        np.testing.assert_array_equal(np.asarray(rec.pp_crc),
                                      np.asarray(sent.pp_crc))
        assert C.as_dict(st.counters)["recirculations"] == 8
        st, out = merge(cfg, st, rec)
        w0, l0 = wire_bytes(pkts)
        w1, l1 = wire_bytes(out)
        assert jnp.all(w0 == w1) and jnp.all(l0 == l1)

    def test_partial_second_pass_parks_whole_payload(self):
        """Payload in (160, 352): the remainder parks entirely."""
        cfg = ParkConfig(capacity=64, max_exp=2, pmax=1024,
                         recirculation=True)
        st = init_state(cfg)
        pkts = mk(1, 4, HDR_BYTES + 200)
        st, sent = split(cfg, st, pkts)
        st, rec = recirc(cfg, st, sent)
        assert jnp.all(rec.payload_len == 0)
        st, out = merge(cfg, st, rec)
        assert jnp.all(wire_bytes(out)[0] == wire_bytes(pkts)[0])

    def test_retry_claims_freed_slot(self):
        cfg = ParkConfig(capacity=4, max_exp=10, pmax=1024,
                         recirculation=True)
        st = init_state(cfg)
        a, b = mk(2, 4, 300), mk(3, 4, 300)
        st, sa = split(cfg, st, a)
        st, sb = split(cfg, st, b)          # table full: all ENB=0
        assert int(jnp.sum(sb.pp_enb)) == 0
        st, _ = merge(cfg, st, sa)          # frees the slots
        st, rb = recirc(cfg, st, sb)        # retry succeeds
        assert int(jnp.sum(rb.pp_enb)) == 4
        st, mb = merge(cfg, st, rb)
        assert jnp.all(wire_bytes(mb)[0] == wire_bytes(b)[0])

    def test_continuation_skips_evicted_slot(self):
        """A slot evicted between the passes must not be overwritten; the
        stale tag then drops as a premature eviction at Merge."""
        cfg = ParkConfig(capacity=4, max_exp=1, pmax=1024,
                         recirculation=True)
        st = init_state(cfg)
        first = mk(4, 4, 500)
        st, s1 = split(cfg, st, first)
        st, s2 = split(cfg, st, mk(5, 4, 500))  # wraps: evicts batch 1
        assert C.as_dict(st.counters)["evictions"] == 4
        st, r1 = recirc(cfg, st, s1)            # lost slots: no extension
        np.testing.assert_array_equal(np.asarray(r1.payload_len),
                                      np.asarray(s1.payload_len))
        st, m1 = merge(cfg, st, r1)
        assert not bool(jnp.any(m1.alive))
        assert C.as_dict(st.counters)["premature_evictions"] == 4
        # batch 2's payloads are intact: their rows were never touched
        st, r2 = recirc(cfg, st, s2)
        st, m2 = merge(cfg, st, r2)
        assert jnp.all(wire_bytes(m2)[0] == wire_bytes(mk(5, 4, 500))[0])

    def test_dead_lane_rows_are_noops(self):
        cfg = ParkConfig(capacity=16, max_exp=2, pmax=512,
                         recirculation=True)
        st = init_state(cfg)
        from repro.core.packet import dead_batch
        st2, out = recirc(cfg, st, dead_batch(8, 512))
        assert C.as_dict(st2.counters) == C.as_dict(st.counters)
        assert not bool(jnp.any(out.alive))


class TestBudget:
    def test_admission_order_and_denial(self):
        cfg = ParkConfig(capacity=64, max_exp=2, pmax=1024,
                         recirculation=True)
        st = init_state(cfg)
        pkts = mk(6, 8, 500)                 # all want a second pass
        st, out = split(cfg, st, pkts)
        fwd, lane, denied = E.recirc_select(cfg, out, 3)
        assert int(denied) == 5
        assert int(jnp.sum(lane.alive)) == 3
        assert int(jnp.sum(fwd.alive)) == 5
        # admitted rows are the first three in arrival order
        np.testing.assert_array_equal(np.asarray(lane.pp_ti),
                                      np.asarray(out.pp_ti[:3]))

    def test_budget_drops_counted_in_engine(self):
        cfg = ParkConfig(capacity=256, max_exp=2, pmax=1024,
                         recirculation=True, recirc_frac=1 / 64)
        pkts = fixed(500).make_batch(jax.random.key(7), 256, pmax=1024)
        res = E.run_engine(cfg, Chain((MacSwap(),)),
                           to_time_major(pkts, 64), window=2)
        assert res.counters["recirc_budget_drops"] > 0
        assert res.counters["recirculations"] > 0
        assert (res.counters["recirculations"]
                + res.counters["recirc_budget_drops"]) >= 256

    def test_zero_budget_disables_lane(self):
        """recirc_frac below one packet per chunk = lane off: behaves
        exactly like recirculation=False scheduling (just wider rows)."""
        cfg = ParkConfig(capacity=256, max_exp=2, pmax=1024,
                         recirculation=True, recirc_frac=0.0)
        assert E.recirc_slots(cfg, 64) == 0
        pkts = fixed(500).make_batch(jax.random.key(8), 128, pmax=1024)
        res = E.run_engine(cfg, Chain((MacSwap(),)),
                           to_time_major(pkts, 64), window=1)
        assert res.counters["recirculations"] == 0
        assert res.counters["recirc_budget_drops"] == 0


class TestEngineRecirc:
    def test_engine_matches_loop_oracle(self):
        """Recirculation ON: scanned engine bit-identical to the host-loop
        mirror, drops and explicit drops included."""
        pkts = enterprise().make_batch(jax.random.key(9), 256, pmax=1024)
        rules = tuple(int(ip) for ip in
                      np.unique(np.asarray(pkts.src_ip))[:40].tolist())
        chain = Chain((Firewall(rules=rules), Nat()))
        cfg = ParkConfig(capacity=96, max_exp=4, pmax=1024,
                         recirculation=True)
        for ed in (False, True):
            a = simulate(cfg, chain, pkts, window=3, chunk=64,
                         explicit_drops=ed)
            b = simulate_loop(cfg, chain, pkts, window=3, chunk=64,
                              explicit_drops=ed)
            _assert_same_result(a, b)

    def test_recirc_port_telemetry(self):
        """The lane's admissions are metered as recirculation-port traffic;
        engine and loop mirror agree field-for-field."""
        pkts = fixed(500).make_batch(jax.random.key(16), 256, pmax=1024)
        chain = Chain((MacSwap(),))
        cfg = ParkConfig(capacity=256, max_exp=4, pmax=1024,
                         recirculation=True)
        res = E.run_engine(cfg, chain, to_time_major(pkts, 64), window=2)
        t = res.telemetry
        assert t.recirc_pkts == res.counters["recirculations"]
        assert t.recirc_pkts > 0
        assert t.recirc_bytes > 0
        # recirculated packets reach the server exactly once
        assert t.to_server_pkts == 256
        loop = simulate_loop(cfg, chain, pkts, window=2, chunk=64)
        assert loop.telemetry == t

    def test_off_still_matches_seed_loop(self):
        """Recirculation OFF (including a recirc-capable config with the
        flag off) stays bit-identical to the seed loop."""
        pkts = enterprise().make_batch(jax.random.key(10), 256, pmax=1024)
        cfg = ParkConfig(capacity=128, max_exp=2, pmax=1024,
                         recirculation=False)
        a = simulate(cfg, Chain((MacSwap(),)), pkts, window=2, chunk=64)
        b = simulate_loop(cfg, Chain((MacSwap(),)), pkts, window=2, chunk=64)
        assert a.counters["recirculations"] == 0
        _assert_same_result(a, b)

    def test_gain_above_off_at_high_occupancy(self):
        """≥90% table occupancy: recirculation-on goodput gain must beat
        recirculation-off (the §6.2.5 / Fig. 13 direction)."""
        pkts = fixed(600).make_batch(jax.random.key(11), 256, pmax=1024)
        trace = to_time_major(pkts, 64)
        chain = Chain((MacSwap(),))
        kw = dict(capacity=64, max_exp=8, pmax=1024)
        r_off = E.run_engine(ParkConfig(**kw), chain, trace, window=4)
        r_on = E.run_engine(ParkConfig(recirculation=True, **kw), chain,
                            trace, window=4)
        assert r_off.peak_occupancy >= 0.9 * 64
        assert r_on.counters["skip_occupied"] > 0
        g_off = E.goodput_gain(r_off)["goodput_gain"]
        g_on = E.goodput_gain(r_on)["goodput_gain"]
        assert g_on > g_off

    def test_recirc_functional_equivalence(self):
        """Wire-level equivalence holds through the recirculation lane:
        merged output equals the whole-packet baseline (paper §6.2.6)."""
        from repro.switchsim.simulate import baseline_roundtrip
        pkts = fixed(700).make_batch(jax.random.key(12), 128, pmax=1024)
        chain = Chain((MacSwap(),))
        cfg = ParkConfig(capacity=256, max_exp=2, pmax=1024,
                         recirculation=True)
        res = simulate(cfg, chain, pkts, window=2, chunk=64)
        base_out, _, _ = baseline_roundtrip(chain, pkts)
        got_w, _ = wire_bytes(_cat(res.merged))
        want_w, _ = wire_bytes(base_out)
        # merged keeps arrival order per chunk but recirculated packets
        # re-emerge one step later in lane rows: compare as multisets of
        # alive wire serializations.
        got = {bytes(r) for r in np.asarray(got_w) if r.any()}
        want = {bytes(r) for r in np.asarray(want_w) if r.any()}
        assert got == want
        assert res.counters["premature_evictions"] == 0
        assert res.counters["merges"] == 128


class TestGoodputBaseline:
    def test_drop_aware_baseline_excludes_dropped_return_trip(self):
        pkts = fixed(512).make_batch(jax.random.key(13), 256, pmax=1024)
        rules = tuple(int(ip) for ip in
                      np.unique(np.asarray(pkts.src_ip))[:64].tolist())
        chain = Chain((Firewall(rules=rules), Nat()))
        cfg = ParkConfig(capacity=512, max_exp=2, pmax=1024)
        res = E.run_engine(cfg, chain, to_time_major(pkts, 64), window=1)
        g = E.goodput_gain(res)
        dropped_bytes = res.wire_bytes - res.ret_bytes
        assert dropped_bytes > 0  # the firewall dropped something
        assert g["baseline_link_bytes"] == res.wire_bytes + res.ret_bytes
        assert g["baseline_naive_link_bytes"] == 2 * res.wire_bytes
        assert g["baseline_link_bytes"] < g["baseline_naive_link_bytes"]
        assert g["goodput_gain"] < g["goodput_gain_naive"]

    def test_baselines_agree_without_drops(self):
        pkts = fixed(512).make_batch(jax.random.key(14), 128, pmax=1024)
        cfg = ParkConfig(capacity=256, max_exp=2, pmax=1024)
        res = E.run_engine(cfg, Chain((MacSwap(),)),
                           to_time_major(pkts, 64), window=1)
        assert res.ret_bytes == res.wire_bytes
        g = E.goodput_gain(res)
        assert g["goodput_gain"] == g["goodput_gain_naive"]


class TestConfigValidation:
    @pytest.mark.parametrize("kw", [
        dict(capacity=0), dict(pmax=0), dict(max_exp=0),
        dict(min_park_len=0), dict(max_clk=1),
        dict(recirc_frac=-0.1), dict(recirc_frac=1.5),
    ])
    def test_bad_config_raises(self, kw):
        with pytest.raises(ValueError):
            ParkConfig(**kw)

    def test_pmax_narrower_than_row_roundtrips(self):
        """pmax < park_bytes (easy with 352B rows) must clamp, not crash."""
        cfg = ParkConfig(capacity=32, max_exp=2, pmax=128, min_park_len=64,
                         recirculation=True)
        st = init_state(cfg)
        pkts = mk(15, 8, HDR_BYTES + 100, pmax=128)
        st, sent = split(cfg, st, pkts)
        assert int(jnp.sum(sent.pp_enb)) == 8
        st, rec = recirc(cfg, st, sent)
        st, out = merge(cfg, st, rec)
        assert jnp.all(wire_bytes(out)[0] == wire_bytes(pkts)[0])
