"""Per-arch reduced-config smoke tests: instantiate the same family at tiny
dimensions and run one forward/train/decode step on CPU, asserting output
shapes and no NaNs (assignment brief requirement)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs.reduced import reduced
from repro.configs.shapes import SHAPES, applicable
from repro.models.lm import LM
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import TrainConfig, init_train_state, train_step

B, S = 2, 32
ARCHS = configs.names()


def batch_for(cfg, key, s=S):
    b = {
        "tokens": jax.random.randint(key, (B, s), 0, cfg.vocab_size,
                                     dtype=jnp.int32),
        "labels": jax.random.randint(jax.random.key(99), (B, s), 0,
                                     cfg.vocab_size, dtype=jnp.int32),
    }
    if cfg.family == "vlm":
        b["positions"] = jnp.broadcast_to(
            jnp.arange(s)[None, None], (3, B, s)).astype(jnp.int32)
        b["vision_embeds"] = 0.01 * jax.random.normal(
            jax.random.key(5), (B, 8, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.enc_layers:
        b["enc_frames"] = 0.1 * jax.random.normal(
            jax.random.key(6), (B, s, cfg.d_model)).astype(jnp.bfloat16)
    return b


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = reduced(configs.get(name))
            lm = LM(cfg)
            cache[name] = (lm, lm.init_params(jax.random.key(0)))
        return cache[name]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(models, arch):
    lm, params = models(arch)
    cfg = lm.cfg
    logits, aux = lm.forward_train(params, batch_for(cfg, jax.random.key(1)))
    assert logits.shape == (B, S, cfg.vocab_padded())
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(models, arch):
    lm, _ = models(arch)
    state = init_train_state(lm, jax.random.key(0))
    tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-3, warmup_steps=1,
                                         total_steps=10))
    batch = batch_for(lm.cfg, jax.random.key(2))
    new_state, metrics = train_step(lm, tcfg, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    moved = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), state["params"],
        new_state["params"])
    assert any(jax.tree.leaves(moved))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_step(models, arch):
    lm, params = models(arch)
    cfg = lm.cfg
    batch = batch_for(cfg, jax.random.key(3))
    del batch["labels"]
    logits, cache = lm.prefill(params, batch, cache_len=S + 4)
    assert logits.shape == (B, cfg.vocab_padded())
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out, cache2 = lm.decode_step(params, cache, tok,
                                 jnp.full((B,), S, jnp.int32))
    assert out.shape == (B, cfg.vocab_padded())
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ARCHS)
def test_long_context_applicability_matrix(arch):
    """DESIGN.md §4: long_500k runs iff the arch is sub-quadratic."""
    cfg = configs.get(arch)
    ok, why = applicable(cfg, SHAPES["long_500k"])
    assert ok == cfg.sub_quadratic
    if not ok:
        assert "quadratic" in why


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_numbers_match_assignment(arch):
    """The registry carries the exact published dimensions."""
    cfg = configs.get(arch)
    expected = {
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "mamba2-1.3b": (48, 2048, 64, 0, 0, 50280),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected
    if arch == "mixtral-8x7b":
        assert cfg.moe.num_experts == 8 and cfg.moe.top_k == 2
    if arch == "deepseek-v2-236b":
        assert cfg.moe.num_experts == 160 and cfg.moe.top_k == 6
        assert cfg.mla.kv_lora_rank == 512 and cfg.moe.shared_experts == 2
    if arch == "mamba2-1.3b":
        assert cfg.ssm.d_state == 128
