"""Unified dataplane-backend layer (repro.backend, DESIGN.md §9).

Covers the registry contract (one ref + one Pallas impl per primitive),
BackendConfig resolution, cross-layer parity (ref ≡ pallas_interpret
bit-exact per primitive AND through the full engine), golden vectors
captured from the pre-refactor jnp math (Firewall / MaglevLB / tag CRC must
be unchanged), the removal of the retired ``use_kernel`` kwarg (now a
``TypeError`` everywhere), and the scenario runner's ``backend`` grid axis
with the engine≡loop oracle in both recirculation modes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.scenarios as S
from repro.backend import (BACKENDS, PRIMITIVES, BackendConfig, as_config,
                           coerce_backend, dispatch, primitive)
from repro.core.header import crc16_tag, tag_valid
from repro.core.packet import make_udp_batch, to_time_major, wire_bytes
from repro.core.park import (ParkConfig, init_state, merge_fn, recirc_fn,
                             split_fn)
from repro.nf.chain import Chain
from repro.nf.firewall import Firewall
from repro.nf.maglev import MaglevLB
from repro.nf.nat import Nat
from repro.switchsim import engine as E
from repro.switchsim.simulate import simulate, simulate_loop


class TestBackendConfig:
    def test_backend_names_validated(self):
        with pytest.raises(ValueError, match="unknown backend"):
            BackendConfig("cuda")
        with pytest.raises(ValueError, match="unknown backend"):
            BackendConfig("ref", (("crc16_tag", "cuda"),))
        with pytest.raises(ValueError, match="unknown primitive"):
            BackendConfig("ref", (("bogus", "ref"),))

    def test_auto_resolves_per_platform(self):
        cfg = BackendConfig("auto")
        want = "pallas" if jax.default_backend() == "tpu" else "ref"
        assert cfg.resolve("crc16_tag") == want
        assert cfg.concrete() == BackendConfig(want)

    def test_overrides_dict_normalized_and_ordered(self):
        a = BackendConfig("ref", {"maglev_select": "pallas_interpret",
                                  "crc16_tag": "pallas_interpret"})
        b = BackendConfig("ref", (("crc16_tag", "pallas_interpret"),
                                  ("maglev_select", "pallas_interpret")))
        # hash() here deliberately exercises BackendConfig's hashability
        # (the jit-static-arg contract); exempt from RPL003 via the
        # replint baseline — nothing persistent is built from the value
        assert a == b and hash(a) == hash(b)
        assert a.resolve("maglev_select") == "pallas_interpret"
        assert a.resolve("payload_store") == "ref"

    def test_concrete_drops_redundant_overrides(self):
        cfg = BackendConfig("pallas_interpret",
                            {"crc16_tag": "pallas_interpret",
                             "acl_match": "ref"})
        assert cfg.concrete() == BackendConfig(
            "pallas_interpret", (("acl_match", "ref"),))

    def test_as_config_spellings(self):
        assert as_config(None) == BackendConfig()
        assert as_config("ref") == BackendConfig("ref")
        cfg = BackendConfig("pallas_interpret")
        assert as_config(cfg) is cfg
        with pytest.raises(TypeError, match="backend must be"):
            as_config(42)

    def test_coerce_is_pure_backend_validation(self):
        assert coerce_backend() == BackendConfig().concrete()
        assert coerce_backend("ref") == BackendConfig("ref")
        assert coerce_backend("auto") == coerce_backend(None)
        with pytest.raises(ValueError, match="unknown backend"):
            coerce_backend("cuda")

    def test_coerce_rejects_retired_use_kernel(self):
        with pytest.raises(TypeError):
            coerce_backend(use_kernel=True)

    def test_registry_matches_the_declared_primitive_set(self):
        assert set(PRIMITIVES) == {"crc16_tag", "acl_match", "maglev_select",
                                   "payload_store", "payload_fetch"}
        for name in PRIMITIVES:
            p = primitive(name)
            assert callable(p.ref) and callable(p.pallas)
        with pytest.raises(KeyError, match="unknown primitive"):
            dispatch("bogus")
        assert "auto" in BACKENDS

    def test_dispatch_ref_returns_the_registry_ref(self):
        assert dispatch("acl_match", "ref") is primitive("acl_match").ref


def _pkts(key=4, n=300, size=300, pmax=512):
    return make_udp_batch(jax.random.key(key), n, size, pmax=pmax)


class TestPrimitiveParity:
    """Every registry primitive: ref ≡ pallas_interpret bit-exact on
    randomized batches (the cross-layer parity satellite)."""

    @pytest.mark.parametrize("n", [1, 5, 300, 1024])
    def test_crc16_tag(self, n):
        ks = jax.random.split(jax.random.key(0), 2)
        ti = jax.random.randint(ks[0], (n,), 0, 1 << 16, dtype=jnp.int32)
        clk = jax.random.randint(ks[1], (n,), 1, 1 << 16, dtype=jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(dispatch("crc16_tag", "ref")(ti, clk)),
            np.asarray(dispatch("crc16_tag", "pallas_interpret")(ti, clk)))

    @pytest.mark.parametrize("b,r", [(7, 1), (500, 20)])
    def test_acl_match(self, b, r):
        ks = jax.random.split(jax.random.key(1), 2)
        ips = jax.random.randint(ks[0], (b,), 0, 60, dtype=jnp.int32)
        rules = jax.random.randint(ks[1], (r,), 0, 60, dtype=jnp.int32)
        got_r = dispatch("acl_match", "ref")(ips, rules)
        got_p = dispatch("acl_match", "pallas_interpret")(ips, rules)
        assert got_r.dtype == got_p.dtype == jnp.bool_
        np.testing.assert_array_equal(np.asarray(got_r), np.asarray(got_p))

    @pytest.mark.parametrize("b", [3, 300])
    def test_maglev_select(self, b):
        lb = MaglevLB()
        st = lb.init_state()
        p = _pkts(n=b)
        args = (p.src_ip, p.dst_ip, p.src_port, p.dst_port, p.proto,
                st["table"], st["backend_ips"])
        np.testing.assert_array_equal(
            np.asarray(dispatch("maglev_select", "ref")(*args)),
            np.asarray(dispatch("maglev_select", "pallas_interpret")(*args)))

    @pytest.mark.parametrize("m,w,b", [(16, 160, 8), (64, 352, 24)])
    def test_payload_store_fetch(self, m, w, b):
        ks = jax.random.split(jax.random.key(2), 4)
        table = jax.random.randint(ks[0], (m, w), 0, 256,
                                   dtype=jnp.int32).astype(jnp.uint8)
        payload = jax.random.randint(ks[1], (b, w), 0, 256,
                                     dtype=jnp.int32).astype(jnp.uint8)
        # unique rows: Split's sequential tagger never hands out the same
        # slot twice in one batch (duplicate-scatter order is unspecified)
        idx = jax.random.permutation(ks[2], m)[:b].astype(jnp.int32)
        enb = jax.random.bernoulli(ks[3], 0.7, (b,))
        t_r = dispatch("payload_store", "ref")(table, payload, idx, enb)
        t_p = dispatch("payload_store", "pallas_interpret")(
            table, payload, idx, enb)
        np.testing.assert_array_equal(np.asarray(t_r), np.asarray(t_p))
        g_r, c_r = dispatch("payload_fetch", "ref")(t_r, idx, enb)
        g_p, c_p = dispatch("payload_fetch", "pallas_interpret")(
            t_p, idx, enb)
        np.testing.assert_array_equal(np.asarray(g_r), np.asarray(g_p))
        np.testing.assert_array_equal(np.asarray(c_r), np.asarray(c_p))


class TestGoldenVectors:
    """Pre-refactor outputs captured from main: the registry's ref impls
    must reproduce the old in-module jnp math bit-for-bit."""

    # crc16_tag(ti = arange(16)*37 % 4096, clk = (arange(16)*101 + 1) % 65536)
    CRC_GOLDEN = [47089, 44615, 18521, 7240, 32657, 27213, 45146, 54014,
                  36192, 60446, 27164, 58320, 9670, 29071, 8083, 50827]
    # make_udp_batch(key(42), 24, 300, pmax=512): Firewall(rules=src_ip[:5])
    # drop mask and MaglevLB() dst_ip rewrites
    FW_GOLDEN = [1, 1, 1, 1, 1] + [0] * 19
    LB_GOLDEN = [167772420, 167772421, 167772416, 167772421, 167772416,
                 167772416, 167772417, 167772422, 167772419, 167772423,
                 167772418, 167772420, 167772416, 167772416, 167772417,
                 167772417, 167772423, 167772420, 167772422, 167772418,
                 167772423, 167772422, 167772418, 167772416]

    @pytest.mark.parametrize("backend", ["ref", "pallas_interpret"])
    def test_crc16_tag_unchanged(self, backend):
        ti = jnp.arange(16, dtype=jnp.int32) * 37 % 4096
        clk = (jnp.arange(16, dtype=jnp.int32) * 101 + 1) % 65536
        got = crc16_tag(ti, clk, backend=backend)
        assert np.asarray(got).tolist() == self.CRC_GOLDEN
        assert bool(jnp.all(tag_valid(ti, clk, got, backend=backend)))

    @pytest.mark.parametrize("backend", ["ref", "pallas_interpret"])
    def test_firewall_unchanged(self, backend):
        p = make_udp_batch(jax.random.key(42), 24, 300, pmax=512)
        fw = Firewall(rules=tuple(int(x) for x in
                                  np.asarray(p.src_ip[:5]).tolist()))
        _, out, drop, cycles = fw(fw.init_state(), p, backend=backend)
        assert np.asarray(drop).astype(int).tolist() == self.FW_GOLDEN
        assert cycles == 70.0
        np.testing.assert_array_equal(
            np.asarray(out.alive), ~np.asarray(drop))

    @pytest.mark.parametrize("backend", ["ref", "pallas_interpret"])
    def test_maglev_unchanged(self, backend):
        p = make_udp_batch(jax.random.key(42), 24, 300, pmax=512)
        lb = MaglevLB()
        _, out, _, _ = lb(lb.init_state(), p, backend=backend)
        assert np.asarray(out.dst_ip).tolist() == self.LB_GOLDEN


CFG = ParkConfig(capacity=64, max_exp=2, pmax=1024)


class TestRetiredUseKernel:
    """The ``use_kernel`` kwarg got its one deprecation cycle in PR 5 and
    is now gone end-to-end: every former acceptor raises ``TypeError``."""

    def test_split_merge_recirc_reject_use_kernel(self):
        st0 = init_state(CFG)
        pkts = make_udp_batch(jax.random.key(3), 16, 400, pmax=1024)
        with pytest.raises(TypeError, match="use_kernel"):
            split_fn(CFG, st0, pkts, use_kernel=True)
        st, sent = split_fn(CFG, st0, pkts, backend="pallas_interpret")
        with pytest.raises(TypeError, match="use_kernel"):
            merge_fn(CFG, st, sent, use_kernel=True)
        rc = ParkConfig(capacity=64, max_exp=2, pmax=1024,
                        recirculation=True)
        st_r, sent_r = split_fn(rc, init_state(rc), pkts)
        with pytest.raises(TypeError, match="use_kernel"):
            recirc_fn(rc, st_r, sent_r, use_kernel=False)

    def test_simulate_and_engine_reject_use_kernel(self):
        pkts = make_udp_batch(jax.random.key(5), 64, 300, pmax=512)
        cfg = ParkConfig(capacity=64, max_exp=2, pmax=512)
        chain = Chain((Nat(),))
        with pytest.raises(TypeError, match="use_kernel"):
            simulate(cfg, chain, pkts, window=1, chunk=32, use_kernel=True)
        with pytest.raises(TypeError, match="use_kernel"):
            simulate_loop(cfg, chain, pkts, window=1, chunk=32,
                          use_kernel=False)
        traces = jax.tree.map(lambda a: a[None], to_time_major(pkts, 32))
        with pytest.raises(TypeError, match="use_kernel"):
            E.run_pipes(cfg, chain, traces, window=1, use_kernel=False)
        with pytest.raises(TypeError, match="use_kernel"):
            E.run_engine(cfg, chain, to_time_major(pkts, 32), window=1,
                         use_kernel=True)

    def test_backend_spelling_still_works_everywhere(self):
        pkts = make_udp_batch(jax.random.key(5), 64, 300, pmax=512)
        cfg = ParkConfig(capacity=64, max_exp=2, pmax=512)
        chain = Chain((Nat(),))
        a = simulate(cfg, chain, pkts, window=1, chunk=32,
                     backend="pallas_interpret")
        b = simulate(cfg, chain, pkts, window=1, chunk=32, backend="ref")
        assert a.counters == b.counters
        assert a.telemetry == b.telemetry


class TestEngineBackends:
    def _setup(self, recirc=False):
        pkts = make_udp_batch(jax.random.key(0), 128, 300, pmax=512)
        chain = Chain((Firewall(rules=(int(pkts.src_ip[0]),)), Nat(),
                       MaglevLB()))
        cfg = ParkConfig(capacity=64, max_exp=4, pmax=512,
                         recirculation=recirc)
        return cfg, chain, pkts

    @pytest.mark.parametrize("recirc", [False, True])
    def test_engine_bit_exact_across_backends(self, recirc):
        cfg, chain, pkts = self._setup(recirc)
        tr = to_time_major(pkts, 32)
        res = {b: E.run_engine(cfg, chain, tr, window=1, backend=b)
               for b in ("ref", "pallas_interpret")}
        a, b = res["ref"], res["pallas_interpret"]
        assert a.counters == b.counters
        assert a.telemetry == b.telemetry
        wa = wire_bytes(jax.tree.map(
            lambda x: x.reshape((-1,) + x.shape[2:]), a.merged))
        wb = wire_bytes(jax.tree.map(
            lambda x: x.reshape((-1,) + x.shape[2:]), b.merged))
        np.testing.assert_array_equal(np.asarray(wa[0]), np.asarray(wb[0]))
        np.testing.assert_array_equal(np.asarray(wa[1]), np.asarray(wb[1]))

    def test_cycle_costs_probe_through_the_dispatch(self, monkeypatch):
        cfg, chain, pkts = self._setup()
        assert chain.cycle_costs(backend="pallas_interpret") == \
            chain.cycle_costs(backend="ref") == chain.cycle_costs()
        seen = []
        import repro.nf.firewall as fw_mod
        real = fw_mod.dispatch
        monkeypatch.setattr(fw_mod, "dispatch",
                            lambda name, backend=None:
                            (seen.append((name, backend)),
                             real(name, backend))[1])
        chain.cycle_costs(backend="pallas_interpret")
        assert ("acl_match", "pallas_interpret") in seen


class TestScenarioBackendAxis:
    def _grid(self, recirc_vals=(False,)):
        base = S.ScenarioSpec(
            name="", workload=("fixed", 512), chain=("fw", "nat", "lb"),
            capacity=64, packets=128, chunk=32, window=1, pmax=512,
            flows=32, fw_rules=4)
        return S.grid(base, "b_{backend}_r{recirc}",
                      backend=["ref", "pallas_interpret"],
                      recirc=list(recirc_vals))

    def test_backend_is_a_compile_key_axis(self):
        specs = self._grid()
        pkts = S.make_packets(specs[0])
        chain = S.build_chain(specs[0], pkts)
        keys = {S.compile_key(s, chain, 4) for s in specs}
        assert len(keys) == len(specs)  # one compiled program per backend

    def test_batched_equals_solo_with_backend_axis(self):
        """The batched≡solo bit-exactness invariant with ``backend`` as a
        grid axis: every point must equal its solo run_engine on the same
        backend, and the two backends must agree with each other."""
        specs = self._grid()
        results = S.run_matrix(specs)
        from repro.core.packet import to_time_major as ttm
        for spec, res in zip(specs, results):
            pkts = S.make_packets(spec)
            chain = S.build_chain(spec, pkts)
            solo = E.run_engine(spec.park_config(), chain,
                                ttm(pkts, spec.chunk), window=spec.window,
                                backend=spec.backend_config())
            assert res.counters == solo.counters
            assert res.telemetry == solo.telemetry
            assert res.gain == E.goodput_gain(solo)
        a, b = results
        assert a.counters == b.counters and a.telemetry == b.telemetry

    @pytest.mark.parametrize("recirc", [False, True])
    def test_verify_oracle_per_backend_both_recirc_modes(self, recirc):
        for res in S.run_matrix(self._grid(recirc_vals=(recirc,))):
            S.verify_oracle(res)  # raises OracleMismatch on divergence

    def test_backend_recorded_in_spec_provenance(self):
        spec = self._grid()[1]
        assert spec.backend == "pallas_interpret"
        assert spec.as_dict()["backend"] == "pallas_interpret"
        with pytest.raises(ValueError, match="unknown backend"):
            S.ScenarioSpec(name="x", backend="cuda")
