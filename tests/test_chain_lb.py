"""Maglev LB inside an NF chain: consistent-hashing table properties,
backend stability under §6.3.2 flow steering across pipes, and the
engine ≡ loop bit-exactness oracle for the §7 FW->NAT->LB chain in both
recirculation modes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.scenarios as S
from repro.nf.maglev import MaglevLB, build_table, degraded_table
from repro.traffic.generator import enterprise, steer_pipes


class TestMaglevTable:
    def test_build_is_deterministic(self):
        """The table must not depend on PYTHONHASHSEED: every pipe (and
        every CI process, for committed baselines) must build the same
        consistent-hashing table for the same backend set."""
        backends = MaglevLB().backends
        a = build_table(backends, 251)
        b = build_table(backends, 251)
        np.testing.assert_array_equal(a, b)

    def test_table_is_balanced(self):
        """Maglev's round-robin fill guarantees near-perfect balance:
        every backend owns floor or ceil of table_size / n slots."""
        backends = MaglevLB().backends
        table = build_table(backends, 251)
        counts = np.bincount(table, minlength=len(backends))
        assert counts.min() >= 251 // len(backends)
        assert counts.max() - counts.min() <= 1

    def test_backend_removal_disrupts_minimally(self):
        """Consistent hashing: removing one of n backends must remap the
        removed backend's slots but leave the vast majority of surviving
        backends' slots untouched (the Maglev paper's disruption bound)."""
        backends = MaglevLB().backends
        full = build_table(backends, 251)
        smaller = build_table(backends[:-1], 251)
        removed = len(backends) - 1
        survived = full != removed
        moved = (full != smaller) & survived
        # removed slots must all be reassigned to surviving backends
        assert np.all(smaller[full == removed] != removed)
        assert moved.mean() < 0.35, (
            f"{moved.mean():.2%} of surviving slots remapped")

    def test_kill_recover_round_trip(self):
        """DESIGN.md §10 kill->recover: the degraded table drains the dead
        backend with minimal disruption AND stays balanced; recovery is
        the original table, so the recovered backend regains exactly its
        original share and untouched flows were never remapped."""
        backends = MaglevLB().backends
        dead = 3
        full = build_table(backends, 251)
        down = degraded_table(backends, 251, dead)
        # dead backend fully drained, entries remapped to ORIGINAL indices
        assert not np.any(down == dead)
        assert set(down.tolist()) == set(range(len(backends))) - {dead}
        # minimal disruption among survivors
        survived = full != dead
        moved = (down != full) & survived
        assert moved.mean() < 0.35, (
            f"{moved.mean():.2%} of surviving slots remapped on kill")
        # the degraded table is still (near-)perfectly balanced
        counts = np.bincount(down, minlength=len(backends))
        alive_counts = np.delete(counts, dead)
        assert counts[dead] == 0
        assert alive_counts.min() >= 251 // (len(backends) - 1)
        assert alive_counts.max() - alive_counts.min() <= 1
        # recovery restores bit-identical assignment: the recovered
        # backend regains exactly its original share, and every flow that
        # survived the outage untouched was never remapped at any point
        recovered = build_table(backends, 251)
        np.testing.assert_array_equal(recovered, full)
        assert (recovered == dead).sum() == (full == dead).sum()

    def test_kill_recover_round_trip_per_flow(self):
        """The same round trip observed through MaglevLB's fault hook:
        ctx['lb_up'] flips the table per step, so before/after outputs are
        bit-identical and during-outage remaps stay minimal."""
        pkts = enterprise().make_batch(jax.random.key(21), 256, pmax=256)
        lb = MaglevLB(fault_target=3)
        st = lb.init_state()
        dead_ip = lb.backends[3]
        up = {"lb_up": jnp.asarray(True)}
        down = {"lb_up": jnp.asarray(False)}
        _, before, _, _ = lb(st, pkts, ctx=up)
        _, during, _, _ = lb(st, pkts, ctx=down)
        _, after, _, _ = lb(st, pkts, ctx=up)
        # nothing lands on the dead backend while it is down
        assert dead_ip not in set(np.asarray(during.dst_ip).tolist())
        # flows that were NOT on the dead backend mostly keep their
        # assignment through the outage (minimal disruption, flow level)
        b, d = np.asarray(before.dst_ip), np.asarray(during.dst_ip)
        unaffected = b != dead_ip
        assert (b[unaffected] != d[unaffected]).mean() < 0.35
        # recovery: every flow returns to its pre-fault backend, so the
        # recovered backend regains exactly its original flow share
        np.testing.assert_array_equal(b, np.asarray(after.dst_ip))


class TestBackendStabilityAcrossPipes:
    def test_same_flow_same_backend_in_every_pipe(self):
        """§6.3.2 steering shards flows across per-pipe LB instances; each
        pipe builds its own table state, so a flow must get the same
        backend no matter which pipe (or how many pipes) serves it."""
        pkts = enterprise().make_batch(jax.random.key(11), 256, pmax=256)
        # src_mac is a random int32 per packet: use it as a row key
        macs = np.asarray(pkts.src_mac)
        assert len(np.unique(macs)) == 256, "key collision; pick a new seed"
        lb = MaglevLB()
        _, flat_out, _, _ = lb(lb.init_state(), pkts)
        backend_of = dict(zip(macs.tolist(),
                              np.asarray(flat_out.dst_ip).tolist()))
        for n_pipes in (2, 4):
            shards, _ = steer_pipes(pkts, n_pipes, chunk=32)
            for p in range(n_pipes):
                shard = jax.tree.map(lambda a: a[p], shards)
                _, out, _, _ = lb(lb.init_state(), shard)  # per-pipe state
                alive = np.asarray(shard.alive)
                for mac, ip in zip(np.asarray(shard.src_mac)[alive],
                                   np.asarray(out.dst_ip)[alive]):
                    assert backend_of[int(mac)] == int(ip)

    def test_rewrite_targets_known_backends_only(self):
        pkts = enterprise().make_batch(jax.random.key(12), 128, pmax=256)
        lb = MaglevLB()
        _, out, drop, _ = lb(lb.init_state(), pkts)
        assert not bool(jnp.any(drop)), "LB never drops"
        assert set(np.asarray(out.dst_ip).tolist()) <= set(lb.backends)


def _chain_spec(**kw) -> S.ScenarioSpec:
    kw.setdefault("name", "chainlb")
    kw.setdefault("workload", ("datacenter",))
    kw.setdefault("chain", ("fw", "nat", "lb"))
    kw.setdefault("capacity", 64)
    kw.setdefault("max_exp", 4)
    kw.setdefault("packets", 128)
    kw.setdefault("chunk", 32)
    kw.setdefault("window", 1)
    kw.setdefault("pmax", 512)
    kw.setdefault("flows", 64)
    kw.setdefault("fw_rules", 8)
    return S.ScenarioSpec(**kw)


class TestChainLBOracle:
    """Engine ≡ loop (counters + telemetry) for the §7 chain, both modes."""

    @pytest.mark.parametrize("recirc", [False, True])
    def test_engine_matches_loop_single_pipe(self, recirc):
        spec = _chain_spec(name=f"recirc_{recirc}", recirc=recirc)
        res = S.run_matrix([spec])[0]
        S.verify_oracle(res)  # raises OracleMismatch on any divergence
        if recirc:
            assert res.counters["recirculations"] > 0
        assert res.counters["splits"] > 0
        # the firewall drops ~fw_rules/flows of the traffic; drops must
        # show up as a thinner return link
        t = res.telemetry
        assert t.from_server_pkts < t.to_server_pkts

    def test_engine_matches_loop_across_pipes(self):
        spec = _chain_spec(name="pipes2", pipes=2, packets=256)
        res = S.run_matrix([spec])[0]
        S.verify_oracle(res)

    def test_sec7_direction_mini(self):
        """The bench_chain assertion at test scale: positive parking gain
        on datacenter traffic, strictly higher with recirculation."""
        off = _chain_spec(name="off")
        on = dataclasses.replace(off, name="on", recirc=True)
        res = {r.spec.name: r for r in S.run_matrix([off, on])}
        g_off = res["off"].gain["goodput_gain"]
        g_on = res["on"].gain["goodput_gain"]
        assert g_off > 0
        assert g_on > g_off
