"""Core PayloadPark: unit tests + hypothesis property tests (paper Alg. 1/2)."""
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install .[test])")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import counters as C
from repro.core.header import crc16_tag
from repro.core.packet import (HDR_BYTES, OP_DROP, PP_HDR_BYTES,
                               make_udp_batch, wire_bytes)
from repro.core.park import (PARK_BYTES_BASE, PARK_BYTES_RECIRC, ParkConfig,
                             init_state, merge, occupancy, recirc, split)

CFG = ParkConfig(capacity=64, max_exp=2, pmax=1024)


def mk(key, n, size, **kw):
    return make_udp_batch(jax.random.key(key), n, size, pmax=1024, **kw)


class TestSplit:
    def test_parks_large_payloads(self):
        st_ = init_state(CFG)
        pkts = mk(0, 8, 300)
        st2, out = split(CFG, st_, pkts)
        assert int(jnp.sum(out.pp_enb)) == 8
        # payload truncated by exactly 160B; +7B PP header on the wire
        assert jnp.all(out.payload_len == pkts.payload_len - PARK_BYTES_BASE)
        assert jnp.all(out.pkt_len() == pkts.pkt_len() - PARK_BYTES_BASE
                       + PP_HDR_BYTES)
        assert C.as_dict(st2.counters)["splits"] == 8
        assert int(occupancy(st2)) == 8

    def test_small_payloads_skip_with_header(self):
        """<160B payloads still get the PP header, ENB=0 (paper §6.1)."""
        st_ = init_state(CFG)
        pkts = mk(0, 8, 150)  # payload 108 < 160
        st2, out = split(CFG, st_, pkts)
        assert int(jnp.sum(out.pp_enb)) == 0
        assert bool(jnp.all(out.pp_valid))
        assert C.as_dict(st2.counters)["skip_small_payload"] == 8
        assert int(occupancy(st2)) == 0

    def test_exactly_160_parks(self):
        st_ = init_state(CFG)
        pkts = mk(0, 4, HDR_BYTES + 160)
        _, out = split(CFG, st_, pkts)
        assert int(jnp.sum(out.pp_enb)) == 4
        assert jnp.all(out.payload_len == 0)

    def test_crc_on_header(self):
        st_ = init_state(CFG)
        _, out = split(CFG, st_, mk(0, 4, 300))
        assert jnp.all(out.pp_crc == crc16_tag(out.pp_ti, out.pp_clk))

    def test_table_full_disables_split(self):
        cfg = ParkConfig(capacity=4, max_exp=10, pmax=1024)
        st_ = init_state(cfg)
        st_, out1 = split(cfg, st_, mk(0, 4, 300))
        assert int(jnp.sum(out1.pp_enb)) == 4
        # table now full; EXP=10 means nothing evicts on one more pass
        st_, out2 = split(cfg, st_, mk(1, 4, 300))
        assert int(jnp.sum(out2.pp_enb)) == 0
        assert C.as_dict(st_.counters)["skip_occupied"] == 4

    def test_eviction_after_exp_wraps(self):
        """EXP=1: one full wrap evicts abandoned payloads (paper §4)."""
        cfg = ParkConfig(capacity=4, max_exp=1, pmax=1024)
        st_ = init_state(cfg)
        st_, _ = split(cfg, st_, mk(0, 4, 300))   # fill, never merged
        st_, out = split(cfg, st_, mk(1, 4, 300))  # wrap: evict + reclaim
        assert int(jnp.sum(out.pp_enb)) == 4
        assert C.as_dict(st_.counters)["evictions"] == 4


class TestMerge:
    def test_roundtrip_wire_identical(self):
        st_ = init_state(CFG)
        pkts = mk(0, 16, 300)
        want_w, want_l = wire_bytes(pkts)
        st_, sent = split(CFG, st_, pkts)
        st_, merged = merge(CFG, st_, sent)
        got_w, got_l = wire_bytes(merged)
        assert jnp.all(got_w == want_w) and jnp.all(got_l == want_l)
        assert int(occupancy(st_)) == 0
        d = C.as_dict(st_.counters)
        assert d["merges"] == 16 and d["premature_evictions"] == 0

    def test_enb0_forwarded_header_removed(self):
        st_ = init_state(CFG)
        st_, sent = split(CFG, st_, mk(0, 8, 150))
        st_, out = merge(CFG, st_, sent)
        assert not bool(jnp.any(out.pp_valid))
        assert bool(jnp.all(out.alive))
        assert C.as_dict(st_.counters)["disabled_returns"] == 8

    def test_premature_eviction_detected_and_dropped(self):
        cfg = ParkConfig(capacity=4, max_exp=1, pmax=1024)
        st_ = init_state(cfg)
        st_, sent1 = split(cfg, st_, mk(0, 4, 300))
        st_, _ = split(cfg, st_, mk(1, 4, 300))   # evicts batch 1's payloads
        st_, out = merge(cfg, st_, sent1)         # stale generations
        assert not bool(jnp.any(out.alive))
        assert C.as_dict(st_.counters)["premature_evictions"] == 4

    def test_crc_corruption_dropped(self):
        st_ = init_state(CFG)
        st_, sent = split(CFG, st_, mk(0, 4, 300))
        bad = sent.replace(pp_crc=sent.pp_crc ^ 1)
        st_, out = merge(CFG, st_, bad)
        assert not bool(jnp.any(out.alive))
        assert C.as_dict(st_.counters)["crc_failures"] == 4

    def test_explicit_drop_frees_slot(self):
        st_ = init_state(CFG)
        st_, sent = split(CFG, st_, mk(0, 4, 300))
        dropped = sent.replace(pp_op=jnp.full_like(sent.pp_op, OP_DROP),
                               payload_len=jnp.zeros_like(sent.payload_len))
        st_, out = merge(CFG, st_, dropped)
        assert int(occupancy(st_)) == 0
        assert C.as_dict(st_.counters)["explicit_drops"] == 4
        assert not bool(jnp.any(out.alive))  # notifications are consumed

    def test_double_merge_is_premature(self):
        st_ = init_state(CFG)
        st_, sent = split(CFG, st_, mk(0, 4, 300))
        st_, _ = merge(CFG, st_, sent)
        st_, out = merge(CFG, st_, sent)  # replay
        assert not bool(jnp.any(out.alive))
        assert C.as_dict(st_.counters)["premature_evictions"] == 4


class TestRecirculation:
    """Pass-based recirculation (paper §6.2.5, DESIGN.md §6): Split parks
    one pass width (160B); ``recirc`` is the second traversal that fills
    the 352B row.  The full lane/budget suite is tests/test_recirc.py."""

    def test_recirc_parks_352_over_two_passes(self):
        cfg = ParkConfig(capacity=64, max_exp=2, pmax=1024,
                         recirculation=True)
        assert cfg.park_bytes == PARK_BYTES_RECIRC == 352
        assert cfg.pass_bytes == PARK_BYTES_BASE == 160
        st_ = init_state(cfg)
        pkts = mk(0, 8, 500)   # payload 458 >= 160
        st_, sent = split(cfg, st_, pkts)
        assert jnp.all(sent.payload_len == pkts.payload_len - 160)
        st_, sent = recirc(cfg, st_, sent)
        assert jnp.all(sent.payload_len == pkts.payload_len - 352)
        st_, out = merge(cfg, st_, sent)
        w0, _ = wire_bytes(pkts)
        w1, _ = wire_bytes(out)
        assert jnp.all(w0 == w1)

    def test_recirc_partial_park(self):
        """Payload in [160, 352): the whole payload parks after the second
        pass (variable length, DESIGN.md deviation note)."""
        cfg = ParkConfig(capacity=64, max_exp=2, pmax=1024,
                         recirculation=True)
        st_ = init_state(cfg)
        pkts = mk(0, 8, HDR_BYTES + 200)
        st_, sent = split(cfg, st_, pkts)
        assert jnp.all(sent.payload_len == 200 - 160)
        st_, sent = recirc(cfg, st_, sent)
        assert jnp.all(sent.payload_len == 0)
        st_, out = merge(cfg, st_, sent)
        w0, _ = wire_bytes(pkts)
        w1, _ = wire_bytes(out)
        assert jnp.all(w0 == w1)


@settings(max_examples=15, deadline=None)
@given(
    sizes=st.lists(st.integers(HDR_BYTES, 900), min_size=1, max_size=40),
    capacity=st.integers(4, 64),
    max_exp=st.integers(1, 3),
)
def test_property_fifo_roundtrip(sizes, capacity, max_exp):
    """For any packet stream and table geometry, FIFO split->merge with the
    table large enough (in-flight = one batch <= capacity) is byte-exact and
    counter-consistent: splits == merges, occupancy returns to 0."""
    cfg = ParkConfig(capacity=capacity, max_exp=max_exp, pmax=1024)
    st_ = init_state(cfg)
    n = len(sizes)
    pkts = make_udp_batch(jax.random.key(7), n, jnp.asarray(sizes), pmax=1024)
    w0, l0 = wire_bytes(pkts)
    st_, sent = split(cfg, st_, pkts)
    st_, out = merge(cfg, st_, sent)
    d = C.as_dict(st_.counters)
    if n <= capacity:
        # no same-batch wrap: every parked payload must merge back
        assert d["premature_evictions"] == 0
        got_w, got_l = wire_bytes(out)
        assert jnp.all(got_w == w0) and jnp.all(got_l == l0)
        assert int(occupancy(st_)) == 0
    # conservation: every split was merged, evicted, or is still parked
    assert d["splits"] == d["merges"] + d["evictions"] + int(occupancy(st_))
    assert d["premature_evictions"] <= d["evictions"]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_unique_live_tags(seed):
    """All live (parked) slots hold distinct tags; tags never use clk=0."""
    cfg = ParkConfig(capacity=16, max_exp=2, pmax=1024)
    st_ = init_state(cfg)
    pkts = make_udp_batch(jax.random.key(seed), 12, 400, pmax=1024)
    st_, sent = split(cfg, st_, pkts)
    live = st_.meta_exp > 0
    clks = st_.meta_clk[live]
    assert jnp.all(clks > 0)
    assert len(set(map(int, clks))) == int(live.sum())


def test_backend_paths_match():
    """ref vs pallas_interpret through split/merge (the retired kernel
    toggle's TypeError contract is covered by tests/test_backend.py)."""
    st0 = init_state(CFG)
    pkts = mk(3, 16, 400)
    st_a, sent_a = split(CFG, st0, pkts, backend="ref")
    st_b, sent_b = split(CFG, st0, pkts, backend="pallas_interpret")
    assert jnp.all(st_a.ptable == st_b.ptable)
    assert jnp.all(sent_a.payload == sent_b.payload)
    st_a2, out_a = merge(CFG, st_a, sent_a, backend="ref")
    st_b2, out_b = merge(CFG, st_b, sent_b, backend="pallas_interpret")
    assert jnp.all(out_a.payload == out_b.payload)
    assert jnp.all(st_a2.ptable == st_b2.ptable)
