"""replint (repro.analysis): per-rule fixtures + framework behaviour.

Each rule gets a bad fixture it MUST fire on and a good twin it MUST stay
silent on; the bad fixtures double as the CLI exit-code matrix (ISSUE 7
acceptance: non-zero on each rule's fixture).  Two regression fixtures
reproduce real past defects: the PR 4 salted-``hash()`` Maglev table build
(RPL003) and the acl_match wrapper that swallowed ``interpret`` (RPL006).
The RPL002 test injects a counter into a fake engine module and asserts
the parity rule demands the loop mirror.
"""
from __future__ import annotations

import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import ALL_RULES, analyze, load_baseline, load_project
from repro.analysis.baseline import render_baseline
from repro.analysis.cli import main
from repro.analysis.rules import rule_by_id

REPO = Path(__file__).resolve().parents[1]


def write_tree(root: Path, files: dict[str, str]) -> None:
    for rel, text in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))


def run_replint(tmp_path: Path, files: dict[str, str], rule_id=None):
    write_tree(tmp_path, files)
    rules = [rule_by_id(rule_id)] if rule_id else ALL_RULES
    return analyze(load_project([tmp_path], root=tmp_path), rules)


def fired(findings) -> set[str]:
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# RPL001 — dispatch discipline
# ---------------------------------------------------------------------------

RPL001_BAD = {"nf/fw.py": """\
    from repro.backend.ref import acl_match

    def route(ips, rules):
        return acl_match(ips, rules)
    """}

RPL001_GOOD = {"nf/fw.py": """\
    from repro.backend.registry import dispatch
    from repro.core.header import crc16_tag

    def route(ips, rules, backend):
        return dispatch("acl_match", backend)(ips, rules)

    def tag(ti, clk, backend):
        return crc16_tag(ti, clk, backend=backend)
    """}


def test_rpl001_fires_on_primitive_import_and_call(tmp_path):
    findings = run_replint(tmp_path, RPL001_BAD, "RPL001")
    assert len(findings) == 2  # the import and the call
    assert all(f.rule == "RPL001" for f in findings)
    assert all(f.path == "nf/fw.py" for f in findings)


def test_rpl001_silent_on_dispatch_and_backend_kwarg(tmp_path):
    assert run_replint(tmp_path, RPL001_GOOD, "RPL001") == []


def test_rpl001_exempts_backend_and_kernels_and_tests(tmp_path):
    files = {
        "backend/registry.py": "from repro.backend.ref import acl_match\n",
        "kernels/acl/ref.py": "from repro.backend.ref import acl_match\n",
        "tests/test_kernels.py": "from repro.backend.ref import acl_match\n",
    }
    assert run_replint(tmp_path, files, "RPL001") == []


# ---------------------------------------------------------------------------
# RPL002 — engine≡loop structural parity
# ---------------------------------------------------------------------------

def _parity_tree(engine_extra: str = "", loop_extra: str = ""):
    return {
        "switchsim/engine.py": f"""\
            from repro.core import counters as C

            def run(state):
                state = C.bump(state, "fault_drops", 1)
            {engine_extra}
                ys = dict(wire_pkts=1, wire_bytes=2)
                return ys
            """,
        "switchsim/simulate.py": f"""\
            from repro.core.counters import bump

            def simulate_loop(state, tel):
                state = bump(state, "fault_drops", 1)
            {loop_extra}
                tel["wire_pkts"] += 1
                tel["wire_bytes"] += 1
                return tel
            """,
        "switchsim/telemetry.py": """\
            import dataclasses

            @dataclasses.dataclass(frozen=True)
            class LinkTelemetry:
                wire_pkts: int = 0
                wire_bytes: int = 0
            """,
    }


def test_rpl002_flags_counter_injected_only_into_engine(tmp_path):
    """The satellite case: add a counter to the (fake) engine without the
    loop mirror — parity must fail lint, naming the counter."""
    bump = '    state = C.bump(state, "injected_counter", 1)'
    findings = run_replint(tmp_path, _parity_tree(engine_extra=bump),
                           "RPL002")
    assert len(findings) == 1
    f = findings[0]
    assert "injected_counter" in f.message and f.path == "switchsim/engine.py"


def test_rpl002_flags_counter_only_in_loop(tmp_path):
    bump = '    state = bump(state, "loop_only", 1)'
    findings = run_replint(tmp_path, _parity_tree(loop_extra=bump), "RPL002")
    assert len(findings) == 1
    assert "loop_only" in findings[0].message
    assert findings[0].path == "switchsim/simulate.py"


def test_rpl002_flags_unmirrored_telemetry_field(tmp_path):
    tree = _parity_tree()
    tree["switchsim/telemetry.py"] = textwrap.dedent("""\
        import dataclasses

        @dataclasses.dataclass(frozen=True)
        class LinkTelemetry:
            wire_pkts: int = 0
            wire_bytes: int = 0
            recirc_pkts: int = 0
        """)
    findings = run_replint(tmp_path, tree, "RPL002")
    # neither side surfaces recirc_pkts: one finding per side
    assert len(findings) == 2
    assert all("recirc_pkts" in f.message for f in findings)


def test_rpl002_silent_when_mirrored(tmp_path):
    assert run_replint(tmp_path, _parity_tree(), "RPL002") == []


def test_rpl002_real_tree_is_parity_clean():
    project = load_project([REPO / "src" / "repro" / "switchsim"], root=REPO)
    assert analyze(project, [rule_by_id("RPL002")]) == []


# ---------------------------------------------------------------------------
# RPL003 — nondeterminism ban (the PR 4 salted-hash() Maglev class)
# ---------------------------------------------------------------------------

MAGLEV_PR4_BUG = {"nf/maglev.py": """\
    def build_table(backends, size=64):
        # the PR 4 defect: builtin hash() of a str is PYTHONHASHSEED-salted,
        # so each process builds a different permutation table
        table = [-1] * size
        for i, name in enumerate(backends):
            offset = hash(name) % size
            skip = hash(name + "skip") % (size - 1) + 1
            table[(offset + i * skip) % size] = i
        return table
    """}

MAGLEV_FIXED = {"nf/maglev.py": """\
    def _mix64(x):
        x = (x ^ (x >> 30)) * 0xBF58476D1CE4E5B9 & (2**64 - 1)
        x = (x ^ (x >> 27)) * 0x94D049BB133111EB & (2**64 - 1)
        return x ^ (x >> 31)

    def build_table(backends, size=64):
        table = [-1] * size
        for i, _ in enumerate(backends):
            offset = _mix64(i) % size
            skip = _mix64(i * 2 + 1) % (size - 1) + 1
            table[(offset + i * skip) % size] = i
        return table
    """}


def test_rpl003_catches_the_pr4_maglev_hash_bug(tmp_path):
    findings = run_replint(tmp_path, MAGLEV_PR4_BUG, "RPL003")
    assert len(findings) == 2  # both salted hash() calls
    assert all("hash()" in f.message for f in findings)


def test_rpl003_silent_on_splitmix_fix(tmp_path):
    assert run_replint(tmp_path, MAGLEV_FIXED, "RPL003") == []


def test_rpl003_flags_wallclock_and_set_iteration(tmp_path):
    files = {"core/build.py": """\
        import time

        def stamp():
            return time.time()

        def order(names):
            out = []
            for n in set(names):
                out.append(n)
            return out
        """}
    findings = run_replint(tmp_path, files, "RPL003")
    assert len(findings) == 2
    msgs = " ".join(f.message for f in findings)
    assert "time.time" in msgs and "iterating a set" in msgs


def test_rpl003_silent_on_sorted_set(tmp_path):
    files = {"core/build.py": """\
        def order(names):
            return [n for n in sorted(set(names))]
        """}
    assert run_replint(tmp_path, files, "RPL003") == []


# ---------------------------------------------------------------------------
# RPL004 — recompile hazards
# ---------------------------------------------------------------------------

def test_rpl004_flags_nonfrozen_config(tmp_path):
    files = {"serving/engine.py": """\
        import dataclasses

        @dataclasses.dataclass
        class EngineConfig:
            max_batch: int = 8
        """}
    findings = run_replint(tmp_path, files, "RPL004")
    assert len(findings) == 1 and "EngineConfig" in findings[0].message


def test_rpl004_silent_on_frozen_config_and_result_types(tmp_path):
    files = {"serving/engine.py": """\
        import dataclasses

        @dataclasses.dataclass(frozen=True)
        class EngineConfig:
            max_batch: int = 8

        @dataclasses.dataclass
        class EngineResult:
            merged: list = None
        """}
    assert run_replint(tmp_path, files, "RPL004") == []


def test_rpl004_flags_shape_fstring_only_under_trace(tmp_path):
    files = {"core/shapes.py": """\
        import jax

        @jax.jit
        def traced(x):
            label = f"in={x.shape}"
            return x

        def host(x):
            return f"in={x.shape}"
        """}
    findings = run_replint(tmp_path, files, "RPL004")
    assert len(findings) == 1
    assert "trace time" in findings[0].message


# ---------------------------------------------------------------------------
# RPL005 — host sync in hot paths
# ---------------------------------------------------------------------------

def test_rpl005_flags_syncs_in_traced_functions(tmp_path):
    files = {"switchsim/hot.py": """\
        from functools import partial

        import jax
        import jax.numpy as jnp
        import numpy as np

        @jax.jit
        def tally(x):
            return float(jnp.sum(x))

        def step(c, x):
            n = jnp.sum(x).item()
            return c + n, n

        def drive(xs):
            return jax.lax.scan(step, 0, xs)

        def body(x):
            return np.asarray(x)

        run = partial(jax.jit, static_argnames=("k",))(body)
        """}
    findings = run_replint(tmp_path, files, "RPL005")
    assert len(findings) == 3
    msgs = " ".join(f.message for f in findings)
    assert "float()" in msgs and ".item()" in msgs and "np.asarray" in msgs


def test_rpl005_silent_on_host_side_finalize(tmp_path):
    files = {"switchsim/hot.py": """\
        import jax.numpy as jnp
        import numpy as np

        def finalize(ys):
            return int(np.asarray(ys["occ"]).max())

        def cast_config(cfg):
            return int(cfg.pipes)
        """}
    assert run_replint(tmp_path, files, "RPL005") == []


def test_rpl005_scoped_to_hot_dirs(tmp_path):
    files = {"launch/report.py": """\
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            return float(jnp.sum(x))
        """}
    assert run_replint(tmp_path, files, "RPL005") == []


def test_rpl005_fires_inside_shard_map_body_under_distributed(tmp_path):
    # the fabric-sharding scope extension (DESIGN.md §12): shard_map bodies
    # are traced code, and distributed/ is a hot dir now
    files = {"distributed/fab.py": """\
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.experimental.shard_map import shard_map

        def body(x):
            return np.asarray(jnp.sum(x))

        run = shard_map(body, mesh=None, in_specs=None, out_specs=None)
        """}
    findings = run_replint(tmp_path, files, "RPL005")
    assert len(findings) == 1 and "np.asarray" in findings[0].message


def test_rpl005_silent_on_host_side_code_under_distributed(tmp_path):
    files = {"distributed/fab.py": """\
        import numpy as np

        def resolve_devices(pipes, devices):
            return int(np.gcd(pipes, devices))
        """}
    assert run_replint(tmp_path, files, "RPL005") == []


def test_rpl005_real_hot_paths_are_clean():
    project = load_project(
        [REPO / "src" / "repro" / "switchsim",
         REPO / "src" / "repro" / "backend",
         REPO / "src" / "repro" / "distributed"], root=REPO)
    assert analyze(project, [rule_by_id("RPL005")]) == []


# ---------------------------------------------------------------------------
# RPL006 — kernel hygiene (the acl_match interpret-swallow regression)
# ---------------------------------------------------------------------------

def _kernel_pkg(ops_body: str, kernel_sig: str = "x, *, interpret=True"):
    return {
        "kernels/foo/kernel.py": f"""\
            def foo_kernel({kernel_sig}):
                return x
            """,
        "kernels/foo/ref.py": """\
            def foo_ref(x):
                return x
            """,
        "kernels/foo/ops.py": ops_body,
    }


def test_rpl006_catches_dropped_interpret_forward(tmp_path):
    """Regression shape: kernels/acl_match/ops.py took ``interpret`` but
    never passed it on, so backend="pallas" silently ran interpret mode."""
    files = _kernel_pkg("""\
        from repro.kernels.foo.kernel import foo_kernel

        def foo(x, interpret: bool = True):
            return foo_kernel(x)
        """)
    findings = run_replint(tmp_path, files, "RPL006")
    assert len(findings) == 1
    assert "does not forward interpret" in findings[0].message


def test_rpl006_silent_when_interpret_forwarded(tmp_path):
    files = _kernel_pkg("""\
        from repro.kernels.foo.kernel import foo_kernel

        def foo(x, interpret: bool = True):
            return foo_kernel(x, interpret=interpret)
        """)
    assert run_replint(tmp_path, files, "RPL006") == []


def test_rpl006_flags_wrapper_without_interpret_kwarg(tmp_path):
    files = _kernel_pkg("""\
        from repro.kernels.foo.kernel import foo_kernel

        def foo(x):
            return foo_kernel(x, interpret=True)
        """)
    findings = run_replint(tmp_path, files, "RPL006")
    assert len(findings) == 1 and "no interpret kwarg" in findings[0].message


def test_rpl006_flags_signature_mismatch_with_ref(tmp_path):
    files = _kernel_pkg("""\
        from repro.kernels.foo.kernel import foo_kernel

        def foo(x, extra_arg, interpret: bool = True):
            return foo_kernel(x, interpret=interpret)
        """)
    findings = run_replint(tmp_path, files, "RPL006")
    assert len(findings) == 1 and "signature" in findings[0].message


def test_rpl006_flags_kernel_without_interpret_path(tmp_path):
    files = _kernel_pkg("""\
        from repro.kernels.foo.kernel import foo_kernel

        def foo(x, interpret: bool = True):
            return foo_kernel(x, interpret=interpret)
        """, kernel_sig="x")
    findings = run_replint(tmp_path, files, "RPL006")
    assert any("no interpret parameter" in f.message for f in findings)


def test_rpl006_real_kernel_packages_are_clean():
    project = load_project([REPO / "src" / "repro" / "kernels"], root=REPO)
    assert analyze(project, [rule_by_id("RPL006")]) == []


# ---------------------------------------------------------------------------
# RPL007 — oracle-test discipline
# ---------------------------------------------------------------------------

RPL007_BAD = {"tests/test_engine.py": """\
    import numpy as np

    def test_engine_matches_loop_bitexact():
        a, b = [1, 2], [1, 2]
        assert np.allclose(a, b)
    """}


def test_rpl007_fires_on_approx_assert_in_exactness_test(tmp_path):
    findings = run_replint(tmp_path, RPL007_BAD, "RPL007")
    assert len(findings) == 1 and "allclose" in findings[0].message


def test_rpl007_silent_on_exact_assert_and_nonexactness_tests(tmp_path):
    files = {"tests/test_engine.py": """\
        import numpy as np

        def test_engine_matches_loop_bitexact():
            assert np.array_equal([1], [1])

        def test_attention_kernel_close_enough():
            # not an exactness oracle: approx compare is fine here
            assert np.allclose([1.0], [1.0 + 1e-9])
        """}
    assert run_replint(tmp_path, files, "RPL007") == []


def test_rpl007_flags_tolerance_kwargs(tmp_path):
    files = {"tests/test_backend.py": """\
        import numpy.testing as npt

        class TestCrossBackendParity:
            def test_backends_match(self):
                npt.assert_array_almost_equal([1.0], [1.0], rtol=1e-6)
        """}
    findings = run_replint(tmp_path, files, "RPL007")
    assert len(findings) == 1 and "rtol=" in findings[0].message


# ---------------------------------------------------------------------------
# CLI + baseline behaviour
# ---------------------------------------------------------------------------

ALL_BAD = {
    "RPL001": RPL001_BAD,
    "RPL002": _parity_tree(
        engine_extra='    state = C.bump(state, "injected", 1)'),
    "RPL003": MAGLEV_PR4_BUG,
    "RPL004": {"core/cfg.py": ("import dataclasses\n\n"
                               "@dataclasses.dataclass\n"
                               "class FooConfig:\n    n: int = 1\n")},
    "RPL005": {"switchsim/hot.py": ("import jax\nimport jax.numpy as jnp\n\n"
                                    "@jax.jit\ndef f(x):\n"
                                    "    return float(jnp.sum(x))\n")},
    "RPL006": _kernel_pkg("""\
        from repro.kernels.foo.kernel import foo_kernel

        def foo(x, interpret: bool = True):
            return foo_kernel(x)
        """),
    "RPL007": RPL007_BAD,
}


@pytest.mark.parametrize("rule_id", sorted(ALL_BAD))
def test_cli_exits_nonzero_on_each_rule_fixture(tmp_path, capsys, rule_id):
    write_tree(tmp_path, ALL_BAD[rule_id])
    rc = main([str(tmp_path), "--no-baseline"])
    out = capsys.readouterr().out
    assert rc == 1
    assert rule_id in out


def test_cli_exit_zero_on_clean_tree_and_json_report(tmp_path, capsys):
    write_tree(tmp_path, RPL001_GOOD)
    report = tmp_path / "replint.json"
    rc = main([str(tmp_path), "--no-baseline", "--json", str(report)])
    assert rc == 0
    data = json.loads(report.read_text())
    assert data["findings"] == [] and data["files_analyzed"] == 1


def test_baseline_suppresses_then_goes_stale(tmp_path, capsys):
    write_tree(tmp_path, RPL001_BAD)
    # same root the CLI will use (cwd), so fingerprint paths line up
    findings = analyze(load_project([tmp_path]), ALL_RULES)
    bl = tmp_path / "bl.json"
    bl.write_text(json.dumps({"suppressions": [
        {"fingerprint": f.fingerprint, "rule": f.rule, "path": f.path,
         "justification": "fixture exemption"} for f in findings]}))
    assert main([str(tmp_path), "--baseline", str(bl)]) == 0
    # fix the violation: every matching entry must now fail as stale
    (tmp_path / "nf" / "fw.py").write_text(
        textwrap.dedent(RPL001_GOOD["nf/fw.py"]))
    rc = main([str(tmp_path), "--baseline", str(bl)])
    out = capsys.readouterr().out
    assert rc == 1 and "STALE" in out


def test_baseline_rejects_empty_justification(tmp_path):
    write_tree(tmp_path, RPL001_BAD)
    findings = analyze(load_project([tmp_path], root=tmp_path), ALL_RULES)
    bl = tmp_path / "bl.json"
    bl.write_text(render_baseline(findings))  # skeleton: justifications empty
    with pytest.raises(ValueError, match="justification"):
        load_baseline(bl)


def test_fingerprints_survive_line_drift_not_content_change(tmp_path):
    write_tree(tmp_path, RPL001_BAD)
    before = analyze(load_project([tmp_path], root=tmp_path), ALL_RULES)
    src = (tmp_path / "nf" / "fw.py").read_text()
    (tmp_path / "nf" / "fw.py").write_text("# a leading comment\n" + src)
    after = analyze(load_project([tmp_path], root=tmp_path), ALL_RULES)
    assert {f.fingerprint for f in before} == {f.fingerprint for f in after}
    assert [f.line for f in before] != [f.line for f in after]


def test_repo_tree_is_clean_under_committed_baseline(monkeypatch):
    """The acceptance criterion, as a test: the shipped tree + shipped
    baseline lint clean."""
    monkeypatch.chdir(REPO)
    assert main(["src", "tests", "--baseline",
                 str(REPO / "replint_baseline.json")]) == 0
