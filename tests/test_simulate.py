"""End-to-end pipeline simulation: functional equivalence (paper §6.2.6),
eviction dynamics (§6.2.4), and link-byte accounting (§6.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packet import HDR_BYTES, wire_bytes
from repro.core.park import ParkConfig
from repro.nf.chain import Chain
from repro.nf.firewall import Firewall
from repro.nf.macswap import MacSwap
from repro.nf.maglev import MaglevLB
from repro.nf.nat import Nat
from repro.switchsim.simulate import baseline_roundtrip, simulate
from repro.traffic.generator import enterprise, fixed


def _cat(batches):
    return jax.tree.map(lambda *xs: jnp.concatenate(xs), *batches)


class TestFunctionalEquivalence:
    """PayloadPark output must be wire-identical to baseline (paper §6.2.6
    validates with identical PCAPs from a MAC-swapper run)."""

    @pytest.mark.parametrize("wl", [fixed(384), fixed(1492), enterprise()])
    def test_macswap_equivalence(self, wl):
        pkts = wl.make_batch(jax.random.key(0), 256, pmax=2048)
        chain = Chain((MacSwap(),))
        cfg = ParkConfig(capacity=256, max_exp=2, pmax=2048)
        res = simulate(cfg, chain, pkts, window=2, chunk=64)
        base_out, _, _ = baseline_roundtrip(chain, pkts)
        got_w, got_l = wire_bytes(_cat(res.merged))
        want_w, want_l = wire_bytes(base_out)
        np.testing.assert_array_equal(np.asarray(got_w), np.asarray(want_w))
        np.testing.assert_array_equal(np.asarray(got_l), np.asarray(want_l))
        assert res.counters["premature_evictions"] == 0

    def test_fw_nat_chain_equivalence(self):
        pkts = enterprise().make_batch(jax.random.key(1), 512, pmax=2048)
        chain = Chain((Firewall(rules=(int(pkts.src_ip[7]),)), Nat(),
                       MaglevLB()))
        cfg = ParkConfig(capacity=512, max_exp=2, pmax=2048)
        res = simulate(cfg, chain, pkts, window=2, chunk=128)
        base_out, _, _ = baseline_roundtrip(chain, pkts)
        got_w, _ = wire_bytes(_cat(res.merged))
        want_w, _ = wire_bytes(base_out)
        np.testing.assert_array_equal(np.asarray(got_w), np.asarray(want_w))


class TestLinkBytes:
    def test_parking_reduces_server_link_bytes(self):
        """The switch->server link carries fewer bytes with parking — the
        paper's goodput mechanism."""
        pkts = fixed(512).make_batch(jax.random.key(2), 256, pmax=2048)
        chain = Chain((MacSwap(),))
        cfg = ParkConfig(capacity=512, max_exp=2, pmax=2048)
        res = simulate(cfg, chain, pkts, window=1, chunk=64)
        # baseline would carry pkt bytes twice (to and from the server)
        baseline_bytes = 2 * res.wire_bytes
        saving = 1 - res.srv_bytes / baseline_bytes
        # 512B packet -> parks 160B, adds 7B header: saving = (160-7)/512
        assert abs(saving - (160 - 7) / 512) < 0.01

    def test_small_packets_add_header_overhead(self):
        """<160B payloads are not parked and pay +7B (paper §7 worst case)."""
        pkts = fixed(150).make_batch(jax.random.key(3), 128, pmax=2048)
        chain = Chain((MacSwap(),))
        cfg = ParkConfig(capacity=512, max_exp=2, pmax=2048)
        res = simulate(cfg, chain, pkts, window=1, chunk=64)
        assert res.srv_bytes > 2 * res.wire_bytes
        assert res.counters["skip_small_payload"] == 128


class TestEvictionDynamics:
    def test_window_exceeding_capacity_causes_premature_evictions(self):
        """In-flight bytes > EXP*capacity -> premature evictions (paper §4,
        Fig. 14's failure mode)."""
        pkts = fixed(384).make_batch(jax.random.key(4), 512, pmax=2048)
        chain = Chain((MacSwap(),))
        cfg = ParkConfig(capacity=64, max_exp=1, pmax=2048)
        res = simulate(cfg, chain, pkts, window=4, chunk=64)  # 256 in flight
        assert res.counters["premature_evictions"] > 0

    def test_explicit_drops_reclaim_faster(self):
        """With a dropping firewall, Explicit Drops free slots immediately;
        without them, dropped packets' payloads squat until expiry
        (paper §6.2.4, Fig. 12)."""
        key = jax.random.key(5)
        pkts = fixed(384).make_batch(key, 512, pmax=2048)
        # block ~25% of source IPs
        rules = tuple(int(ip) for ip in np.unique(
            np.asarray(pkts.src_ip))[:128].tolist())
        chain = Chain((Firewall(rules=rules), Nat()))
        cfg = ParkConfig(capacity=96, max_exp=10, pmax=2048)
        res_no = simulate(cfg, chain, pkts, window=1, chunk=64,
                          explicit_drops=False)
        res_yes = simulate(cfg, chain, pkts, window=1, chunk=64,
                           explicit_drops=True)
        assert res_yes.counters["explicit_drops"] > 0
        # explicit drops -> more successful splits (less squatting)
        assert res_yes.counters["skip_occupied"] <= \
            res_no.counters["skip_occupied"]
        assert res_yes.counters["premature_evictions"] <= \
            res_no.counters["premature_evictions"]
